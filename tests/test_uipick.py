"""UIPiCK tag-filtering semantics (paper §7.1) + work removal (§7.1.1)."""
from repro.testing.proptest import hypothesis, st
import jax
import jax.numpy as jnp
import pytest

from repro.core.counting import count_fn
from repro.core.uipick import (
    ALL_GENERATORS,
    KernelCollection,
    MatchCondition,
    parse_filter_tags,
)
from repro.core.workremoval import remove_work

COLL = KernelCollection(ALL_GENERATORS)


def test_superset_default_single_generator():
    knls = COLL.generate_kernels(
        ["matmul_sq", "dtype:float32", "prefetch:True", "tile:32",
         "n:256,512"])
    assert len(knls) == 2
    assert all(k.tags["prefetch"] and k.tags["dtype"] == "float32"
               for k in knls)


def test_superset_two_tags_matches_nothing():
    # no generator carries BOTH matmul_sq and finite_diff (paper's example)
    knls = COLL.generate_kernels(["matmul_sq", "finite_diff", "n:256",
                                  "n_grid:1024"])
    assert knls == []


def test_intersect_matches_both():
    knls = COLL.generate_kernels(
        ["matmul_sq", "finite_diff", "dtype:float32", "prefetch:False",
         "tile:16", "n:256", "n_grid:1024", "variant:roll"],
        generator_match_cond=MatchCondition.INTERSECT)
    names = {k.name.split("_")[0] for k in knls}
    assert names == {"matmul", "stencil"}


def test_identical_and_subset():
    got = COLL.generate_kernels(
        ["matmul_sq", "matmul", "n:256", "dtype:float32", "prefetch:False",
         "tile:16"], generator_match_cond=MatchCondition.IDENTICAL)
    assert len(got) == 1
    got = COLL.generate_kernels(
        ["matmul_sq", "matmul", "flops", "flops_madd_pattern", "n:256",
         "dtype:float32", "prefetch:False", "tile:16",
         "nelements:4096", "iters:64"],
        generator_match_cond=MatchCondition.SUBSET)
    kinds = {k.name.split("_")[0] for k in got}
    assert kinds == {"matmul", "madd"}


def test_variant_cartesian_product_size():
    knls = COLL.generate_kernels(
        ["flops_madd_pattern", "dtype:float32",
         "nelements:4096,16384", "iters:64,128,256"])
    assert len(knls) == 2 * 3


@hypothesis.given(st.sampled_from(["float32", "bfloat16"]),
                  st.sampled_from([256, 512]))
@hypothesis.settings(max_examples=8, deadline=None)
def test_parse_filter_tags_roundtrip(dtype, n):
    gen_tags, variant = parse_filter_tags(
        ["matmul_sq", f"dtype:{dtype}", f"n:{n}", "prefetch:True"])
    assert gen_tags == {"matmul_sq"}
    assert variant["dtype"] == (dtype,)
    assert variant["n"] == (n,)
    assert variant["prefetch"] == (True,)


def test_kernel_counts_and_timing():
    (knl,) = COLL.generate_kernels(
        ["matmul_sq", "dtype:float32", "prefetch:False", "tile:16", "n:256"])
    c = knl.counts()
    assert c["f_op_float32_madd"] == 256 ** 3
    t = knl.time(trials=3, warmup=1)
    assert 0 < t < 5.0


def test_kernel_time_warmup_zero_does_not_raise():
    """warmup=0 used to hit UnboundLocalError on block_until_ready(out)."""
    (knl,) = COLL.generate_kernels(["empty_kernel", "nelements:16"],
                                   generator_match_cond=MatchCondition.INTERSECT)
    t = knl.time(trials=2, warmup=0)
    assert t > 0


def test_kernel_jit_compiled_once_across_timings():
    """time() must reuse one cached jitted callable instead of re-jitting
    (and re-tracing) on every call."""
    (knl,) = COLL.generate_kernels(["empty_kernel", "nelements:16"],
                                   generator_match_cond=MatchCondition.INTERSECT)
    assert knl._jitted is None
    knl.time(trials=1, warmup=1)
    jf = knl._jitted
    assert jf is not None
    knl.time(trials=1, warmup=0)
    assert knl._jitted is jf
    assert knl.jitted() is jf


# ---------------------------------------------------------------------------
# work removal
# ---------------------------------------------------------------------------


def test_work_removal_preserves_kept_access_and_value():
    def tiled(a, b):
        def body(acc, i):
            ak = jax.lax.dynamic_slice_in_dim(a, i * 16, 16, axis=1)
            bk = jax.lax.dynamic_slice_in_dim(b, i * 16, 16, axis=0)
            return acc + ak @ bk, None

        acc, _ = jax.lax.scan(body, jnp.zeros((64, 64)), jnp.arange(4))
        return acc

    a = jnp.ones((64, 64))
    b = (jnp.arange(64 * 64, dtype=jnp.float32) / 4096).reshape(64, 64)
    stripped = remove_work(tiled, a, b, remove_args=(0,))
    # additive accounting: every kept element read exactly once
    assert float(jax.jit(stripped)(a, b)) == pytest.approx(
        float(jnp.sum(b)), rel=1e-5)
    cs = count_fn(stripped, a, b)
    co = count_fn(tiled, a, b)
    assert cs["f_op_float32_madd"] == 0
    assert co["f_op_float32_madd"] == 64 * 64 * 64
    assert cs["f_mem_gather_float32_load"] == 4096      # b only
    assert co["f_mem_gather_float32_load"] == 8192      # a and b


def test_work_removal_keeps_afr():
    """A *stripped compute site* inside a loop re-reading the same array
    keeps its access-to-footprint ratio (paper: the b-pattern's AFR of
    n/16 survives work removal)."""
    def rereader(x):
        def body(acc, _):
            # tanh is on-chip work → stripped; its read of x is kept
            return acc + jnp.sum(jnp.tanh(x)), None

        acc, _ = jax.lax.scan(body, jnp.float32(0), None, length=5)
        return acc

    x = jnp.ones((128,))
    stripped = remove_work(rereader, x)
    # the tanh site executes 5× → its operand x is read 5× (AFR = 5)
    assert float(jax.jit(stripped)(x)) == pytest.approx(5 * 128, rel=1e-4)
