"""Regression tests for control-flow recursion in the jaxpr feature counter
(paper §5, Algorithm 1): exact counts through nested scan→cond→pjit, and
single-visit accounting for unknown-trip-count ``while`` bodies."""
import jax
import jax.numpy as jnp

from repro.core.counting import count_fn


def test_scan_cond_pjit_nested_exact():
    """A pjit-ed matmul inside a cond branch inside a 5-step scan: the madd
    count must be 5 (scan) × ½ (branch average) × n³, and the scan must
    contribute exactly its trip count to f_sync_loop_steps."""
    inner = jax.jit(lambda v: v @ v)

    def f(x):
        def body(c, _):
            c = jax.lax.cond(c.sum() > 0, inner, lambda v: v, c)
            return c, None

        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    c = count_fn(f, jnp.ones((8, 8)))
    assert c["f_op_float32_madd"] == 5 * (8 ** 3) / 2
    assert c["f_sync_loop_steps"] == 5
    assert c["f_sync_launch_kernel"] == 1


def test_nested_scans_multiply_trip_counts():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci), None

            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = count_fn(f, jnp.ones((16,)))
    assert c["f_op_float32_transc"] == 3 * 4 * 16
    # loop-step bookkeeping: outer contributes 3, each outer step's inner
    # scan contributes 4 → 3 + 3·4
    assert c["f_sync_loop_steps"] == 3 + 3 * 4


def test_while_body_counted_once_with_loop_step():
    """Unknown trip count: the body is charged exactly once (the paper's
    conservative accounting) and f_sync_loop_steps increments by 1."""

    def f(x):
        def cond(c):
            return c[0, 0] < 100.0

        def body(c):
            return c @ c

        return jax.lax.while_loop(cond, body, x)

    c = count_fn(f, jnp.ones((4, 4)))
    assert c["f_op_float32_madd"] == 4 ** 3
    assert c["f_sync_loop_steps"] == 1


def test_while_inside_scan_multiplies_by_scan_length_only():
    """A while body under a 6-step scan is charged 6 × (body once)."""

    def f(x):
        def body(c, _):
            c = jax.lax.while_loop(
                lambda v: jnp.sum(v) < 10.0, lambda v: jnp.tanh(v), c)
            return c, None

        y, _ = jax.lax.scan(body, x, None, length=6)
        return y

    c = count_fn(f, jnp.ones((8,)))
    assert c["f_op_float32_transc"] == 6 * 8
    # 6 scan steps + 6 × one while visit
    assert c["f_sync_loop_steps"] == 6 + 6


def test_while_cond_jaxpr_counted_once():
    """The while predicate's arithmetic must be charged (once per visit,
    alongside the body) — it was previously dropped entirely."""

    def f(x):
        def cond(c):
            return jnp.sum(c) < 10.0       # reduce_sum → 8 float32 adds

        def body(c):
            return jnp.tanh(c)             # 8 transcendentals

        return jax.lax.while_loop(cond, body, x)

    c = count_fn(f, jnp.ones((8,)))
    assert c["f_op_float32_transc"] == 8   # body, once
    assert c["f_op_float32_add"] == 8      # predicate, once
    assert c["f_sync_loop_steps"] == 1


def test_while_cond_inside_scan_charged_per_scan_step():
    def f(x):
        def body(c, _):
            c = jax.lax.while_loop(
                lambda v: jnp.sum(v) < 10.0, lambda v: jnp.tanh(v), c)
            return c, None

        y, _ = jax.lax.scan(body, x, None, length=6)
        return y

    c = count_fn(f, jnp.ones((8,)))
    assert c["f_op_float32_add"] == 6 * 8  # predicate ×6 scan steps


def test_fori_loop_counts_as_scan():
    """fori_loop with static bounds lowers to scan: trip count must be
    applied, not the single-visit while accounting."""

    def f(x):
        return jax.lax.fori_loop(0, 7, lambda i, c: c * 1.5, x)

    c = count_fn(f, jnp.ones((32,)))
    assert c["f_op_float32_mul"] == 7 * 32
    assert c["f_sync_loop_steps"] == 7
