"""The fleet-routing subsystem: health skew loop, router policies and
ledgers, the deterministic scheduler simulation, and the daemon's fleet
endpoints.

The routing guarantees mirror the serving ones, asserted through the
same probes:

* **zero timings** — every routing decision prices the workload on every
  machine from counts alone (``router.timings() == 0``);
* **one evaluation per machine per batch** — ``route_batch`` costs one
  compiled ``predict_batch`` dispatch per fleet machine, regardless of
  batch size;
* **determinism** — the simulator replays a scenario bit-identically,
  which is what lets CI gate on "predictive beats round-robin" exactly.
"""
from __future__ import annotations

import json
import urllib.error
import urllib.request
from typing import Dict

import jax.numpy as jnp
import pytest

from repro.fleet import (
    Degradation,
    FleetHealth,
    FleetRouter,
    HealthEvent,
    heavy_tailed_jobs,
    simulate_fleet,
)
from repro.testing.synthdev import (
    exact_profile,
    fleet_device,
    synthetic_fleet,
)


def _fleet_profiles(n: int = 3):
    fleet = synthetic_fleet(n)
    return fleet, [exact_profile(d) for d in fleet]


def _router(n: int = 3, **kw) -> FleetRouter:
    _fleet, profiles = _fleet_profiles(n)
    return FleetRouter.from_profiles(profiles, **kw)


def _item(size: int = 64):
    return ((lambda x: x + 1.0), (jnp.ones((size,), jnp.float32),))


# ---------------------------------------------------------------------------
# FleetHealth: skew EWMA → demotion → recalibration flag
# ---------------------------------------------------------------------------


def test_health_first_observation_sets_skew():
    h = FleetHealth(alpha=0.25)
    snap = h.observe("m", observed_s=2.0, predicted_s=1.0)
    assert snap.skew == pytest.approx(2.0)
    assert snap.n_obs == 1


def test_health_ewma_converges_to_ratio():
    h = FleetHealth(alpha=0.5)
    for _ in range(20):
        snap = h.observe("m", observed_s=3.0, predicted_s=1.0)
    assert snap.skew == pytest.approx(3.0, rel=1e-4)
    assert snap.degradation == pytest.approx(2.0, rel=1e-4)


def test_health_weight_needs_min_obs():
    h = FleetHealth(min_obs=3)
    h.observe("m", observed_s=10.0, predicted_s=1.0)
    h.observe("m", observed_s=10.0, predicted_s=1.0)
    assert h.weight("m") == 1.0             # under-observed: no demotion
    h.observe("m", observed_s=10.0, predicted_s=1.0)
    assert h.weight("m") == pytest.approx(0.1)


def test_health_healthy_machine_keeps_full_weight():
    h = FleetHealth()
    for _ in range(10):
        h.observe("m", observed_s=1.05, predicted_s=1.0)
    assert h.weight("m") == 1.0             # below demote_skew
    assert h.weight("unknown") == 1.0
    assert h.needs_recalibration() == []


def test_health_weight_floors_at_min_weight():
    h = FleetHealth(min_weight=0.2)
    for _ in range(10):
        h.observe("m", observed_s=100.0, predicted_s=1.0)
    assert h.weight("m") == pytest.approx(0.2)


def test_health_min_weight_one_disables_demotion_keeps_flags():
    h = FleetHealth(min_weight=1.0)
    for _ in range(10):
        h.observe("m", observed_s=4.0, predicted_s=1.0)
    assert h.weight("m") == 1.0
    assert h.needs_recalibration() == ["m"]


def test_health_flag_latches_and_callback_fires_once():
    events = []
    h = FleetHealth(on_recalibrate=events.append)
    for _ in range(10):
        h.observe("m", observed_s=5.0, predicted_s=1.0)
    assert h.needs_recalibration() == ["m"]
    assert len(events) == 1                 # latched: fires exactly once
    assert isinstance(events[0], HealthEvent)
    assert events[0].machine == "m"
    assert "recalibrate" in events[0].hint
    assert h.events == events


def test_health_clear_resets_machine_state():
    h = FleetHealth()
    for _ in range(5):
        h.observe("m", observed_s=5.0, predicted_s=1.0)
    assert h.needs_recalibration() == ["m"]
    h.clear("m")
    assert h.needs_recalibration() == []
    assert h.weight("m") == 1.0
    assert h.skew("m") == 1.0


def test_health_report_is_json_ready():
    h = FleetHealth()
    for _ in range(4):
        h.observe("b", observed_s=3.0, predicted_s=1.0)
        h.observe("a", observed_s=1.0, predicted_s=1.0)
    report = h.report()
    assert list(report) == ["a", "b"]       # deterministic order
    assert report["b"]["flagged"] is True
    assert report["a"]["weight"] == 1.0
    json.dumps(report)                      # must serialize


def test_health_validation():
    with pytest.raises(ValueError):
        FleetHealth(alpha=0.0)
    with pytest.raises(ValueError):
        FleetHealth(min_weight=0.0)
    with pytest.raises(ValueError):
        FleetHealth(demote_skew=2.0, recalibrate_skew=1.5)
    h = FleetHealth()
    with pytest.raises(ValueError):
        h.observe("m", observed_s=1.0, predicted_s=0.0)


# ---------------------------------------------------------------------------
# FleetRouter: construction, policies, ledger
# ---------------------------------------------------------------------------


def test_router_rejects_duplicate_machines():
    _fleet, profiles = _fleet_profiles(2)
    with pytest.raises(ValueError, match="same machine"):
        FleetRouter.from_profiles([profiles[0], profiles[0]])


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown routing policy"):
        _router(2, policy="coin_flip")
    r = _router(2)
    with pytest.raises(ValueError, match="unknown routing policy"):
        r.route(_item(), policy="coin_flip")


def test_round_robin_cycles_in_fleet_order():
    r = _router(3, policy="round_robin")
    placed = [r.route(_item()).machine for _ in range(6)]
    assert placed == r.machines * 2


def test_cheapest_picks_min_predicted_machine():
    r = _router(3, policy="cheapest")
    d = r.route(_item(4096))
    assert d.machine == min(d.predicted, key=d.predicted.get)
    assert d.predicted_s == d.predicted[d.machine]
    assert set(d.predicted) == set(r.machines)


def test_predicted_makespan_spreads_identical_jobs():
    # repeated identical jobs must spread: the ledger charges the chosen
    # machine, so the next copy sees its backlog and goes elsewhere
    r = _router(3)
    placed = [r.route(_item(4096)).machine for _ in range(12)]
    assert len(set(placed)) == 3
    out = r.outstanding()
    assert all(v > 0 for v in out.values())


def test_least_loaded_ignores_job_cost():
    r = _router(3, policy="least_loaded")
    first = r.route(_item(4096))
    second = r.route(_item(4096))
    assert second.machine != first.machine  # first now has backlog


def test_complete_drains_ledger_and_feeds_health():
    r = _router(2)
    d = r.route(_item(4096))
    assert r.outstanding()[d.machine] == pytest.approx(d.predicted_s)
    r.complete(d, observed_s=d.predicted_s * 3.0)
    assert r.outstanding()[d.machine] == 0.0
    assert r.health.skew(d.machine) == pytest.approx(3.0)
    # by-name completion needs the predicted cost
    with pytest.raises(ValueError, match="predicted_s"):
        r.complete(d.machine)
    with pytest.raises(KeyError):
        r.complete("nope", predicted_s=1.0)


def test_demoted_machine_loses_cheapest_routing():
    r = _router(3, policy="cheapest")
    best = r.route(_item(4096), dispatch=False).machine
    for _ in range(5):                      # best machine runs 100x slow
        r.health.observe(best, observed_s=100.0, predicted_s=1.0)
    d = r.route(_item(4096), dispatch=False)
    assert d.machine != best
    assert d.weights[best] < 1.0


def test_route_batch_one_eval_per_machine_zero_timings():
    r = _router(3)
    items = [_item(32 * (i + 1)) for i in range(8)]
    evals_before = {m: r.session(m).eval_calls for m in r.machines}
    decisions = r.route_batch(items)
    assert len(decisions) == 8
    for m in r.machines:
        assert r.session(m).eval_calls - evals_before[m] == 1
    assert r.timings() == 0
    assert [d.seq for d in decisions] == list(range(8))


def test_router_reset_restores_fresh_ledgers():
    r = _router(2)
    d = r.route(_item(4096))
    r.complete(d, observed_s=d.predicted_s * 50)
    r.reset(policy="cheapest")
    assert r.policy == "cheapest"
    assert all(v == 0.0 for v in r.outstanding().values())
    assert r.decisions == 0
    assert r.health.skew(d.machine) == 1.0


def test_router_stats_and_score():
    r = _router(2)
    prices = r.score(_item(4096))
    assert set(prices) == set(r.machines)
    assert all(p > 0 for p in prices.values())
    stats = r.stats()
    assert stats["timings"] == 0
    assert stats["decisions"] == 1          # score() = dispatch=False route
    json.dumps(stats)


def test_router_open_pools_profiles_and_shares_count_engine(tmp_path):
    from repro.profiles.profile import save_profile

    fleet, profiles = _fleet_profiles(3)
    paths = []
    for dev, prof in zip(fleet, profiles):
        p = tmp_path / f"{dev.name}.json"
        save_profile(prof, p)
        paths.append(p)
    r = FleetRouter.open(paths, cache=tmp_path / "cache")
    try:
        assert len(r.machines) == 3
        engines = {id(r.session(m).engine) for m in r.machines}
        assert len(engines) == 1            # ONE count engine, shared
        r.route(_item(64), name="shared")
        # the shared engine traced the workload once for the whole fleet
        assert r.session(r.machines[0]).engine.trace_count == 1
        assert r.timings() == 0
    finally:
        r.close()


def test_router_replace_session_clears_health():
    fleet, profiles = _fleet_profiles(2)
    r = FleetRouter.from_profiles(profiles)
    m = r.machines[0]
    for _ in range(5):
        r.health.observe(m, observed_s=10.0, predicted_s=1.0)
    assert r.health.needs_recalibration() == [m]
    from repro.api import PerfSession
    r.replace_session(m, PerfSession.open(profiles[0]))
    assert r.health.needs_recalibration() == []
    with pytest.raises(KeyError):
        r.replace_session("nope", PerfSession.open(profiles[0]))


# ---------------------------------------------------------------------------
# workload synthesis + synthetic fleet helpers
# ---------------------------------------------------------------------------


def test_heavy_tailed_jobs_deterministic_and_ordered():
    a = heavy_tailed_jobs(40, seed="t")
    b = heavy_tailed_jobs(40, seed="t")
    assert [(j.kernel.name, j.arrival_s) for j in a] \
        == [(j.kernel.name, j.arrival_s) for j in b]
    arrivals = [j.arrival_s for j in a]
    assert arrivals == sorted(arrivals)
    assert all(t > 0 for t in arrivals)
    # a different seed reshuffles the stream
    c = heavy_tailed_jobs(40, seed="u")
    assert [(j.kernel.name, j.arrival_s) for j in c] \
        != [(j.kernel.name, j.arrival_s) for j in a]


def test_heavy_tailed_jobs_n_machines_scales_pressure():
    # the default inter-arrival targets ~2x the aggregate capacity of
    # n_machines reference machines: a bigger fleet gets a denser stream
    # (same kernels, compressed arrivals), so queues still form
    one = heavy_tailed_jobs(30, seed="t")
    four = heavy_tailed_jobs(30, seed="t", n_machines=4)
    assert [j.kernel.name for j in four] == [j.kernel.name for j in one]
    assert four[-1].arrival_s == pytest.approx(one[-1].arrival_s / 4.0)
    with pytest.raises(ValueError):
        heavy_tailed_jobs(5, n_machines=0)


def test_heavy_tailed_jobs_mixes_cheap_and_expensive():
    jobs = heavy_tailed_jobs(60, seed="mix")
    ref = fleet_device("apex")
    model, params = ref.truth_model(), dict(ref.p_true)
    costs = sorted(float(model.evaluate(params, j.kernel.counts()))
                   for j in jobs)
    assert costs[-1] / costs[0] > 50        # genuinely heavy-tailed
    assert costs[len(costs) // 2] < sum(costs) / len(costs)  # skewed


def test_synthetic_fleet_extends_default_and_is_deterministic():
    f3 = synthetic_fleet(3)
    f5 = synthetic_fleet(5)
    assert [d.name for d in f3] == ["apex", "bulk", "citra"]
    assert [d.name for d in f5][:3] == [d.name for d in f3]
    assert [d.name for d in f5][3:] == ["gen3", "gen4"]
    again = synthetic_fleet(5)
    assert [d.p_true for d in again] == [d.p_true for d in f5]
    for d in f5:
        assert all(v > 0 for v in d.p_true.values())
    with pytest.raises(ValueError):
        synthetic_fleet(0)


def test_degraded_device_same_fingerprint_scaled_rates():
    d = fleet_device("apex")
    slow = d.degraded(4.0)
    assert slow.fingerprint == d.fingerprint     # same machine identity
    assert slow.p_true["p_madd"] == pytest.approx(4 * d.p_true["p_madd"])
    assert slow.p_true["p_edge"] == d.p_true["p_edge"]  # shape untouched
    with pytest.raises(ValueError):
        d.degraded(0.0)


def test_exact_profile_predicts_truth_exactly():
    from repro.api import PerfSession

    d = fleet_device("bulk")
    session = PerfSession.open(exact_profile(d))
    jobs = heavy_tailed_jobs(5, seed="x")
    for j in jobs:
        pred = session.predict(j.kernel)
        truth = d.true_time(j.kernel)
        assert pred.seconds == pytest.approx(truth, rel=1e-5)
    assert session.timer.calls == 0


# ---------------------------------------------------------------------------
# the scheduler simulation (the CI gate's claims, at test scale)
# ---------------------------------------------------------------------------


def _sim_setup(n: int = 4, n_jobs: int = 60):
    fleet, profiles = _fleet_profiles(n)
    devices = {d.fingerprint.id: d for d in fleet}
    jobs = heavy_tailed_jobs(n_jobs, seed="test-sim", n_machines=n)
    return profiles, devices, jobs


def test_predictive_routing_beats_round_robin():
    profiles, devices, jobs = _sim_setup()
    r = FleetRouter.from_profiles(profiles, policy="round_robin")
    rr = simulate_fleet(r, devices, jobs)
    r.reset(policy="predicted_makespan")
    pm = simulate_fleet(r, devices, jobs)
    assert pm.makespan_s < rr.makespan_s
    assert rr.routing_timings == 0 and pm.routing_timings == 0
    assert pm.decisions == len(jobs)
    assert sum(int(v["jobs"]) for v in pm.per_machine.values()) == len(jobs)


def test_simulation_is_bit_deterministic():
    profiles, devices, jobs = _sim_setup(3, 40)
    r = FleetRouter.from_profiles(profiles)
    first = simulate_fleet(r, devices, jobs)
    r.reset()
    second = simulate_fleet(r, devices, jobs)
    assert json.dumps(first.to_dict(), sort_keys=True) \
        == json.dumps(second.to_dict(), sort_keys=True)


def test_oracle_is_the_clairvoyant_reference():
    # the oracle is greedy with PERFECT information (true service times
    # and queue states) — not a makespan optimum, so predictive routing
    # may edge past it on some streams; what it must do is crush the
    # model-blind baseline and land in the same regime as the predictive
    # policy (which only has the model)
    profiles, devices, jobs = _sim_setup(3, 40)
    r = FleetRouter.from_profiles(profiles)
    pm = simulate_fleet(r, devices, jobs)
    r.reset(policy="round_robin")
    rr = simulate_fleet(r, devices, jobs)
    oracle = simulate_fleet(None, devices, jobs, oracle=True)
    assert oracle.policy == "oracle"
    assert oracle.makespan_s < rr.makespan_s
    assert abs(oracle.makespan_s - pm.makespan_s) \
        < 0.5 * (rr.makespan_s - min(oracle.makespan_s, pm.makespan_s))
    assert oracle.routing_timings == 0
    assert oracle.decisions == len(jobs)


def test_degraded_device_flags_demotes_and_recovers_makespan():
    profiles, devices, jobs = _sim_setup(4, 80)
    # find the machine predictive routing leans on hardest, degrade it
    probe = FleetRouter.from_profiles(profiles)
    busiest = max(sorted(simulate_fleet(probe, devices, jobs)
                         .per_machine.items()),
                  key=lambda kv: kv[1]["jobs"])[0]
    degradations = [Degradation(machine=busiest, factor=4.0)]

    control = FleetRouter.from_profiles(profiles,
                                        health=FleetHealth(min_weight=1.0))
    undemoted = simulate_fleet(control, devices, jobs,
                               degradations=degradations)
    health = FleetRouter.from_profiles(profiles)
    demoted = simulate_fleet(health, devices, jobs,
                             degradations=degradations)

    assert busiest in demoted.recalibration_flagged
    assert demoted.weights[busiest] < 1.0
    assert demoted.makespan_s <= undemoted.makespan_s
    assert demoted.routing_timings == 0


def test_recalibration_closes_the_loop():
    from repro.api import PerfSession
    from repro.studies.zoo import STUDY_SMOKE_TAGS

    profiles, devices, jobs = _sim_setup(3, 60)
    probe = FleetRouter.from_profiles(profiles)
    busiest = max(sorted(simulate_fleet(probe, devices, jobs)
                         .per_machine.items()),
                  key=lambda kv: kv[1]["jobs"])[0]

    def recalibrate(machine: str):
        # fresh study against the DEGRADED truth, no stale cache
        return PerfSession.open(devices[machine].degraded(4.0),
                                cache=None, tags=STUDY_SMOKE_TAGS,
                                trials=2)

    r = FleetRouter.from_profiles(profiles)
    report = simulate_fleet(
        r, devices, jobs,
        degradations=[Degradation(machine=busiest, factor=4.0)],
        recalibrate_fn=recalibrate)
    assert report.recalibrated == [busiest]
    # the fresh profile describes the degraded machine: flag cleared and
    # post-swap skew settles back toward 1
    assert busiest not in report.recalibration_flagged
    assert report.health.get(busiest, {}).get("skew", 1.0) < 2.0


# ---------------------------------------------------------------------------
# daemon fleet endpoints
# ---------------------------------------------------------------------------


def _tiny_targets(n: int = 4) -> Dict:
    out = {}
    for i in range(n):
        size = 32 * (i + 1)
        out[f"t{i}"] = ((lambda x: x + 1.0),
                        (jnp.ones((size,), jnp.float32),))
    return out


@pytest.fixture
def fleet_daemon():
    from repro.api import PerfSession
    from repro.serving import PredictionDaemon

    _fleet, profiles = _fleet_profiles(2)
    d = PredictionDaemon(PerfSession.open(profiles[0]), port=0,
                         targets=_tiny_targets(),
                         router=FleetRouter.from_profiles(profiles)).start()
    yield d
    d.close()


def _post(url: str, body: dict):
    import urllib.error
    req = urllib.request.Request(
        url, data=json.dumps(body).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=60.0) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_daemon_route_complete_fleet_endpoints(fleet_daemon):
    d = fleet_daemon
    status, body = _post(f"{d.url}/route", {"kernel": "t1"})
    assert status == 200
    assert body["machine"] in d.router.machines
    assert set(body["predicted"]) == set(d.router.machines)
    assert body["predicted_s"] > 0

    status, done = _post(f"{d.url}/complete",
                         {"machine": body["machine"],
                          "predicted_s": body["predicted_s"],
                          "observed_s": body["predicted_s"]})
    assert status == 200 and done["ok"] is True
    assert all(v == 0.0 for v in done["outstanding"].values())

    with urllib.request.urlopen(f"{d.url}/fleet", timeout=30.0) as resp:
        fleet = json.loads(resp.read())
    assert fleet["timings"] == 0
    assert fleet["decisions"] == 1
    assert set(fleet["machines"]) == set(d.router.machines)

    stats = d.stats()
    assert stats["fleet"]["decisions"] == 1


def test_daemon_route_error_codes(fleet_daemon):
    d = fleet_daemon
    assert _post(f"{d.url}/route", {"kernel": "nope"})[0] == 404
    assert _post(f"{d.url}/route", {})[0] == 400
    assert _post(f"{d.url}/route",
                 {"kernel": "t0", "policy": "coin_flip"})[0] == 400
    assert _post(f"{d.url}/complete",
                 {"machine": "nope", "predicted_s": 1.0})[0] == 404
    assert _post(f"{d.url}/complete", {"machine": "x"})[0] == 400


def test_daemon_without_router_returns_503():
    from repro.api import PerfSession
    from repro.serving import PredictionDaemon

    _fleet, profiles = _fleet_profiles(1)
    d = PredictionDaemon(PerfSession.open(profiles[0]), port=0,
                         targets=_tiny_targets()).start()
    try:
        assert _post(f"{d.url}/route", {"kernel": "t0"})[0] == 503
        assert _post(f"{d.url}/complete",
                     {"machine": "m", "predicted_s": 1.0})[0] == 503
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(f"{d.url}/fleet", timeout=30.0)
        assert err.value.code == 503
        assert "fleet" not in d.stats()
    finally:
        d.close()
