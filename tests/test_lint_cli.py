"""``python -m repro.lint`` — golden-file behavior of the auditor CLI.

Every test in this module runs with kernel execution POISONED: timing or
jit-compiling any :class:`MeasurementKernel` raises immediately.  The
whole CLI — default generator + zoo scope included — must pass under
that regime; this is the PR's zero-execution acceptance proof, together
with the report's own ``timings=0`` stats line.

The other pinned properties: ``--json`` output is byte-identical across
runs and sorted by ``(severity, location, code, message)``; fixture
kernels surface ≥ 4 distinct diagnostic classes; the baseline workflow
(write → pass → regress → fail) and ``--suppress`` drive the exit code;
unknown targets exit 2, never traceback.
"""
import json
from pathlib import Path

import pytest

from repro.analysis.cli import main
from repro.core.uipick import MeasurementKernel

REPO = Path(__file__).resolve().parents[1]

FIXTURE_MODULE = '''\
"""Lint fixtures: one kernel per defect class (audited abstractly)."""
import types

import jax
import jax.numpy as jnp

X = jax.ShapeDtypeStruct((64,), jnp.float32)


def unmodeled(x):
    return jnp.cumprod(x)


def trip(x):
    return jax.lax.while_loop(
        lambda c: c[1] < 5, lambda c: (c[0] * 1.5, c[1] + 1), (x, 0))[0]


def mixed(x):
    return (x.astype(jnp.bfloat16) * 2).astype(jnp.float32) + x * 3


def take(x):
    return jnp.take(x, jnp.zeros((4,), jnp.int32))


LINT_TARGETS = [
    types.SimpleNamespace(name=f.__name__, fn=f, args=(X,))
    for f in (unmodeled, trip, mixed, take)
]
'''


@pytest.fixture(autouse=True)
def no_execution(monkeypatch):
    def boom(self, *a, **k):
        raise AssertionError("repro.lint must never execute a kernel")

    monkeypatch.setattr(MeasurementKernel, "time", boom)
    monkeypatch.setattr(MeasurementKernel, "time_stats", boom)
    monkeypatch.setattr(MeasurementKernel, "jitted", boom)


@pytest.fixture()
def fixture_module(tmp_path):
    path = tmp_path / "lint_fixtures.py"
    path.write_text(FIXTURE_MODULE)
    return str(path)


def _run_json(capsys, argv):
    code = main(argv)
    return code, json.loads(capsys.readouterr().out)


def test_fixture_kernels_surface_four_diagnostic_classes(
        capsys, fixture_module):
    code, payload = _run_json(
        capsys, ["--no-default", "--json", fixture_module])
    codes = {d["code"] for d in payload["diagnostics"]}
    assert {"unmodeled-primitive", "while-trip-count", "mixed-precision",
            "data-dependent-access"} <= codes
    assert payload["stats"] == {"timings": 0, "traces": 4}
    assert code == 1                    # un-baselined error → fail


def test_json_output_is_byte_identical_across_runs(capsys, fixture_module):
    main(["--no-default", "--json", fixture_module])
    first = capsys.readouterr().out
    main(["--no-default", "--json", fixture_module])
    second = capsys.readouterr().out
    assert first == second


def test_diagnostics_sorted_by_severity_then_location(
        capsys, fixture_module):
    _code, payload = _run_json(
        capsys, ["--no-default", "--json", fixture_module])
    rank = {"error": 0, "warning": 1, "info": 2}
    keys = [(rank[d["severity"]], d["location"], d["code"], d["message"])
            for d in payload["diagnostics"]]
    assert keys == sorted(keys)
    assert len(keys) >= 4


def test_baseline_workflow_write_pass_regress(capsys, tmp_path,
                                              fixture_module):
    baseline = tmp_path / "baseline.json"
    assert main(["--no-default", fixture_module,
                 "--write-baseline", str(baseline)]) == 0
    capsys.readouterr()
    # adopted errors no longer fail the run
    code, payload = _run_json(
        capsys, ["--no-default", "--json", fixture_module,
                 "--baseline", str(baseline)])
    assert code == 0 and payload["new_errors"] == []
    # an emptied baseline turns them back into regressions
    baseline.write_text(json.dumps({"version": 1, "errors": []}))
    code, payload = _run_json(
        capsys, ["--no-default", "--json", fixture_module,
                 "--baseline", str(baseline)])
    assert code == 1
    assert payload["new_errors"] == ["unmodeled-primitive@kernel:unmodeled"]


def test_suppress_moves_findings_out_of_the_exit_code(
        capsys, fixture_module):
    code, payload = _run_json(
        capsys, ["--no-default", "--json", fixture_module,
                 "--suppress", "unmodeled-primitive"])
    assert code == 0
    assert all(d["code"] != "unmodeled-primitive"
               for d in payload["diagnostics"])
    assert any(d["code"] == "unmodeled-primitive"
               for d in payload["suppressed"])


def test_unknown_module_exits_2(capsys):
    assert main(["--no-default", "no_such_module_xyz"]) == 2
    assert "repro.lint" in capsys.readouterr().err


def test_module_without_targets_exits_2(tmp_path, capsys):
    empty = tmp_path / "empty_mod.py"
    empty.write_text("VALUE = 1\n")
    assert main(["--no-default", str(empty)]) == 2
    assert "lint_targets" in capsys.readouterr().err


def test_default_scope_is_clean_and_execution_free(capsys):
    """The repo's own generators + zoo pass their own linter — with
    execution poisoned, over the full default scope."""
    code, payload = _run_json(capsys, ["--json"])
    assert code == 0
    assert payload["counts"]["error"] == 0
    assert payload["stats"]["timings"] == 0
    assert payload["stats"]["traces"] > 0
    codes = {d["code"] for d in payload["diagnostics"]}
    assert "probe-lattice-divisibility" in codes


def test_kernel_wrappers_lint_clean_against_empty_baseline(capsys):
    """The static cost analyzer opens every Pallas wrapper: the
    checked-in CI baseline is EMPTY, and the wrappers must pass against
    it with zero errors — no ``opaque-primitive``, no
    ``pallas-unanalyzable``."""
    committed = json.loads((REPO / "lint_baseline.json").read_text())
    assert committed["errors"] == []
    code, payload = _run_json(
        capsys, ["--kernels", "--no-default", "--json",
                 "--baseline", str(REPO / "lint_baseline.json")])
    assert code == 0 and payload["new_errors"] == []
    assert payload["counts"]["error"] == 0
    codes = {d["code"] for d in payload["diagnostics"]}
    assert "opaque-primitive" not in codes
    assert "pallas-unanalyzable" not in codes
    assert payload["stats"]["timings"] == 0


def test_stale_baseline_entries_warn_and_prune(capsys, tmp_path,
                                               fixture_module):
    """A baseline entry whose finding no longer occurs is reported as
    stale; ``--prune-baseline`` rewrites the file without it."""
    baseline = tmp_path / "baseline.json"
    assert main(["--no-default", fixture_module,
                 "--write-baseline", str(baseline)]) == 0
    capsys.readouterr()
    ghost = "unmodeled-primitive@kernel:deleted_kernel"
    payload = json.loads(baseline.read_text())
    payload["errors"].append(ghost)
    baseline.write_text(json.dumps(payload))

    code, out = _run_json(
        capsys, ["--no-default", "--json", fixture_module,
                 "--baseline", str(baseline)])
    assert code == 0                        # stale entries never fail a run
    assert out["stale_baseline"] == [ghost]
    assert out["pruned_baseline"] is False
    assert ghost in json.loads(baseline.read_text())["errors"]

    code, out = _run_json(
        capsys, ["--no-default", "--json", fixture_module,
                 "--baseline", str(baseline), "--prune-baseline"])
    assert code == 0
    assert out["stale_baseline"] == [ghost]
    assert out["pruned_baseline"] is True
    kept = json.loads(baseline.read_text())
    assert ghost not in kept["errors"] and kept["errors"]
    # a second run against the pruned file sees nothing stale
    code, out = _run_json(
        capsys, ["--no-default", "--json", fixture_module,
                 "--baseline", str(baseline)])
    assert code == 0 and out["stale_baseline"] == []


def test_prune_baseline_requires_baseline(capsys):
    assert main(["--no-default", "--kernels", "--prune-baseline"]) == 2
    assert "--baseline" in capsys.readouterr().err


def test_all_combos_sweeps_beyond_first_fixed_combo(capsys):
    """``--all-combos`` audits every buildable fixed-argument combination
    of the default generators: still clean, still execution-free, and
    strictly more abstract traces than the representative sweep."""
    _code, first = _run_json(capsys, ["--json"])
    code, swept = _run_json(capsys, ["--json", "--all-combos"])
    assert code == 0
    assert swept["counts"]["error"] == 0
    assert swept["stats"]["timings"] == 0
    assert swept["stats"]["traces"] > first["stats"]["traces"]


def test_example_module_lints_clean(capsys):
    """Satellite: examples/autotune_variants.py exposes lint_targets()
    and audits clean (abstractly — importing it times nothing)."""
    code, payload = _run_json(
        capsys, ["--no-default", "--json",
                 str(REPO / "examples" / "autotune_variants.py")])
    assert code == 0
    assert payload["counts"]["error"] == 0
    assert payload["stats"]["timings"] == 0
