"""Machine-profile persistence: save → load round trip, strict validation
(corrupt files, schema drift, foreign fingerprints), atomic writes."""
import json

import pytest

from repro.core.calibrate import fit_model
from repro.core.model import Model
from repro.profiles import (
    PROFILE_SCHEMA_VERSION,
    DeviceFingerprint,
    MachineProfile,
    ModelFit,
    ProfileError,
    load_profile,
    save_profile,
)

FP = DeviceFingerprint(platform="cpu", device_kind="Test CPU", n_devices=1)


def _fitted_model():
    model = Model("f_wall_time_x", "p_a * f_x + p_b * f_y")
    rows = [{"f_x": float(n ** 3), "f_y": float(n ** 2),
             "f_wall_time_x": 3e-9 * n ** 3 + 7e-10 * n ** 2}
            for n in (64, 96, 128, 192)]
    return model, fit_model(model, rows, nonneg=True)


def _profile(model, fit):
    return MachineProfile(fingerprint=FP,
                          fits={"base": ModelFit.from_fit(model, fit)},
                          trials=8, kernel_names=["k0", "k1"])


def test_roundtrip_reproduces_parameters_exactly(tmp_path):
    model, fit = _fitted_model()
    path = save_profile(_profile(model, fit), tmp_path / "prof.json")
    loaded = load_profile(path, expected_fingerprint=FP)
    mf = loaded.fit_for(model)
    # bit-exact float round trip through JSON
    assert mf.params == fit.params
    assert mf.fit.residual_norm == fit.residual_norm
    assert mf.fit.iterations == fit.iterations
    assert mf.fit.converged == fit.converged
    feats = {"f_x": 1e6, "f_y": 1e4}
    assert float(model.evaluate(mf.params, feats)) \
        == float(model.evaluate(fit.params, feats))
    assert loaded.trials == 8
    assert loaded.kernel_names == ["k0", "k1"]


def test_save_is_deterministic_and_atomic(tmp_path):
    model, fit = _fitted_model()
    p1 = save_profile(_profile(model, fit), tmp_path / "a.json")
    p2 = save_profile(_profile(model, fit), tmp_path / "b.json")
    assert p1.read_text() == p2.read_text()
    assert not list(tmp_path.glob("*.tmp"))


def test_fit_for_unknown_model_names_available_fits(tmp_path):
    model, fit = _fitted_model()
    path = save_profile(_profile(model, fit), tmp_path / "prof.json")
    other = Model("f_wall_time_x", "p_c * f_z")
    with pytest.raises(ProfileError, match="no fit for model"):
        load_profile(path).fit_for(other)


def test_corrupt_profile_fails_with_clear_error(tmp_path):
    path = tmp_path / "prof.json"
    path.write_text("{ this is not json")
    with pytest.raises(ProfileError, match="not valid JSON"):
        load_profile(path)
    path.write_text("[1, 2, 3]")
    with pytest.raises(ProfileError, match="not a JSON object"):
        load_profile(path)


def test_missing_file_raises_profile_error(tmp_path):
    with pytest.raises(ProfileError, match="cannot read profile"):
        load_profile(tmp_path / "nope.json")


def test_old_schema_rejected(tmp_path):
    model, fit = _fitted_model()
    payload = _profile(model, fit).to_dict()
    payload["schema_version"] = PROFILE_SCHEMA_VERSION - 1
    path = tmp_path / "old.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(ProfileError, match="schema version"):
        load_profile(path)


def test_malformed_fields_rejected(tmp_path):
    model, fit = _fitted_model()
    payload = _profile(model, fit).to_dict()
    del payload["fingerprint"]
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(ProfileError, match="malformed profile"):
        load_profile(path)


def test_edited_expression_breaks_signature(tmp_path):
    """Tampering with the stored expression (without refreshing the
    signature) must not silently produce a wrong model."""
    model, fit = _fitted_model()
    payload = _profile(model, fit).to_dict()
    payload["fits"]["base"]["expr"] = "p_a * f_x"
    path = tmp_path / "tampered.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(ProfileError, match="signature mismatch"):
        load_profile(path)


def test_foreign_fingerprint_rejected(tmp_path):
    model, fit = _fitted_model()
    path = save_profile(_profile(model, fit), tmp_path / "prof.json")
    other = DeviceFingerprint(platform="tpu", device_kind="TPU v4",
                              n_devices=8)
    with pytest.raises(ProfileError, match="this machine"):
        load_profile(path, expected_fingerprint=other)
    # without the expectation the load succeeds (shipping profiles around
    # for inspection is legitimate)
    assert load_profile(path).fingerprint == FP


def test_fingerprint_id_is_filename_safe():
    fp = DeviceFingerprint(platform="gpu",
                           device_kind="NVIDIA A100-SXM4/40GB",
                           n_devices=4)
    assert "/" not in fp.id and " " not in fp.id
    assert fp.id.startswith("gpu_")
