"""Straggler-monitor semantics and the trainer's straggler path.

The monitor is the single-machine ancestor of the fleet health layer
(``repro.fleet.FleetHealth``): wall time vs a model-predicted expectation,
flag past ``slack ×``.  The load-bearing property regression-tested here
is window hygiene — flagged samples must stay OUT of the running-median
window, otherwise repeated stragglers inflate the expectation until they
look normal and mask themselves.

The trainer test runs the REAL ``Trainer.train`` loop (timing, monitor
wiring, metrics log) with the expensive parts stubbed: the jitted train
step is replaced by a fake that sleeps on a chosen step, and the data
pipeline by a trivial iterator — so the straggler path is exercised in
milliseconds without compiling a model.
"""
import itertools
import time

import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import InputShape, OptimizerConfig, RunConfig
from repro.runtime import StragglerMonitor, Trainer
from repro.runtime.trainer import TrainState


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------


def test_predicted_expectation_mode():
    mon = StragglerMonitor(slack=2.0, predicted_step_s=0.1)
    assert mon.expectation() == 0.1         # model prediction, immediately
    assert mon.observe(1, 0.15) is None
    ev = mon.observe(2, 0.3)
    assert ev is not None
    assert ev.step == 2
    assert ev.expected_s == 0.1
    assert ev.ratio == pytest.approx(3.0)
    assert mon.events == [ev]


def test_median_fallback_needs_five_samples():
    mon = StragglerMonitor(slack=2.0)
    for i in range(4):
        assert mon.observe(i, 10.0) is None  # no expectation yet
    assert mon.expectation() is None
    mon.observe(4, 10.0)
    assert mon.expectation() == pytest.approx(10.0)
    assert mon.observe(5, 25.0) is not None


def test_median_fallback_uses_windowed_median():
    mon = StragglerMonitor(slack=2.0, window=4)
    for i, t in enumerate([1.0, 1.0, 1.0, 1.0, 1.0]):
        mon.observe(i, t)
    # window drops the early samples: median over the LAST 4
    for i, t in enumerate([0.2, 0.2, 0.2, 0.2], start=5):
        mon.observe(i, t)
    assert mon.expectation() == pytest.approx(0.2)


def test_flagged_samples_stay_out_of_the_window():
    # regression: a run of stragglers must NOT drag the expectation up —
    # if flagged samples entered the window, the 10th identical straggler
    # would look normal and the monitor would go blind
    mon = StragglerMonitor(slack=3.0)
    for i in range(5):
        mon.observe(i, 0.1)
    for i in range(5, 15):
        ev = mon.observe(i, 1.0)
        assert ev is not None, f"straggler at step {i} was masked"
        assert ev.expected_s == pytest.approx(0.1)
    assert mon.expectation() == pytest.approx(0.1)
    assert len(mon._times) == 5             # window holds clean samples only
    assert len(mon.events) == 10


def test_on_straggler_callback_fires_per_event():
    seen = []
    mon = StragglerMonitor(slack=2.0, predicted_step_s=0.1,
                           on_straggler=seen.append)
    mon.observe(1, 0.1)
    mon.observe(2, 0.5)
    mon.observe(3, 0.12)
    mon.observe(4, 0.9)
    assert [e.step for e in seen] == [2, 4]
    assert seen == mon.events


# ---------------------------------------------------------------------------
# Trainer straggler path (real loop, stubbed step + data)
# ---------------------------------------------------------------------------


def _tiny_run(tmp_path, **kw):
    cfg = get_smoke_config("yi-6b")
    shape = InputShape("tiny", seq_len=32, global_batch=8, kind="train")
    return RunConfig(
        model=cfg, shape=shape,
        optimizer=OptimizerConfig(learning_rate=1e-3, warmup_steps=5,
                                  total_steps=100),
        microbatches=2, checkpoint_every=0,
        checkpoint_dir=str(tmp_path / "ckpt"), max_step_retries=3, **kw)


def test_trainer_flags_slow_step_against_model_prediction(
        tmp_path, monkeypatch):
    run = _tiny_run(tmp_path, straggler_slack=3.0)
    tr = Trainer(run, mesh=None, predicted_step_s=0.01)
    flagged = []
    tr.monitor.on_straggler = flagged.append

    # materialize the loss once up front: the first jnp array of the
    # process pays backend init, which would flag step 1 as a straggler
    loss = jnp.float32(1.0)

    def fake_step(params, opt_state, batch):
        # Trainer increments step AFTER the call: this executes step 3
        # when state.step == 2, i.e. on the third call
        if fake_step.calls == 2:
            time.sleep(0.08)                # 8× prediction: a straggler
        fake_step.calls += 1
        return params, opt_state, {"loss": loss}

    fake_step.calls = 0
    monkeypatch.setattr(tr, "_train_step", fake_step)
    monkeypatch.setattr("repro.runtime.trainer.make_batch_iterator",
                        lambda *a, **kw: itertools.repeat(None))

    state = tr.train(TrainState({}, {}, 0), 5, log_every=0)
    assert state.step == 5
    assert [e.step for e in flagged] == [3]
    assert flagged == tr.monitor.events
    assert flagged[0].expected_s == 0.01
    assert flagged[0].ratio > 3.0
    # every step's wall time made it into the metrics log
    walls = [m["wall_s"] for m in tr.metrics_log if "wall_s" in m]
    assert len(walls) == 5
    assert walls[2] > 0.05


def test_trainer_wires_slack_and_prediction_into_monitor(tmp_path):
    run = _tiny_run(tmp_path, straggler_slack=4.5)
    tr = Trainer(run, mesh=None, predicted_step_s=0.25)
    assert tr.monitor.slack == 4.5
    assert tr.monitor.predicted_step_s == 0.25
    # without a model prediction the monitor starts expectation-less
    tr2 = Trainer(run, mesh=None)
    assert tr2.monitor.predicted_step_s is None
    assert tr2.monitor.expectation() is None
