"""Logical-axis sharding rules: resolution, divisibility guard, virtual
axes, activation constraints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.sharding import (
    logical_to_pspec,
    shard_act,
    tree_shardings,
    use_mesh,
)


@pytest.fixture
def mesh():
    return make_mesh((1, 1), ("data", "model"))


def test_basic_resolution(mesh):
    spec = logical_to_pspec(("embed", "ff"), mesh)
    assert spec == P("data", "model")


def test_virtual_dp_axis(mesh):
    spec = logical_to_pspec(("batch", "seq"), mesh)
    assert spec == P("data")


def test_multi_pod_virtual_axes():
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    spec = logical_to_pspec(("batch", None, "ff"), mesh)
    assert spec == P(("pod", "data"), None, "model")


def test_divisibility_guard_abstract():
    # exercise the arithmetic directly with a fake mesh-shape mapping
    from repro.sharding.axes import _axis_size

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = logical_to_pspec(("batch", "kv_seq", "kv_heads", "head_dim"),
                            FakeMesh(), dim_sizes=(128, 32768, 8, 128))
    assert spec == P("data", "model")


def test_no_axis_reuse():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    # both dims want "model": only the first gets it
    spec = logical_to_pspec(("vocab", "ff"), FakeMesh(),
                            dim_sizes=(512, 512))
    assert spec == P("model")


def test_shard_act_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard_act(x, "batch", None)
    np.testing.assert_array_equal(x, y)


def test_shard_act_with_mesh(mesh):
    with use_mesh(mesh):
        x = jnp.ones((4, 4))
        y = shard_act(x, "batch", "act_ff")
        np.testing.assert_array_equal(x, y)


def test_tree_shardings_structure(mesh):
    axes = {"w": ("embed", "ff"), "b": ("ff",)}
    specs = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
             "b": jax.ShapeDtypeStruct((8,), jnp.float32)}
    sh = tree_shardings(axes, specs, mesh=mesh)
    assert sh["w"].spec == P("data", "model")
    assert sh["b"].spec == P("model")
