"""CLI + artifact tests for the cross-machine study subsystem:
`compare`/`merge`/`gc` subcommands, fleet bundles, profile merge rules,
and the `--zoo --synthetic` study path (the CI smoke, in-process)."""
import json

import pytest

from repro.profiles import (
    DeviceFingerprint,
    MachineProfile,
    MeasurementCache,
    ProfileError,
    load_profile,
    merge_profiles,
    save_profile,
)
from repro.profiles.cli import main as cli_main
from repro.studies import (
    STUDY_SMOKE_TAGS,
    fleet_to_dict,
    load_profiles_any,
    merge_any,
    run_study,
)
from repro.testing.synthdev import fleet_device

NOISE = 0.02


def _study_profile(name, **kw):
    device = fleet_device(name, noise=NOISE)
    return device, run_study(fingerprint=device.fingerprint,
                             timer=device.timer, tags=STUDY_SMOKE_TAGS,
                             trials=3, **kw)


# ---------------------------------------------------------------------------
# merge semantics (API)
# ---------------------------------------------------------------------------


def test_merge_same_machine_unions_fits():
    device = fleet_device("apex", noise=NOISE)
    from repro.studies import LIN_FLOP, LIN_FLOP_MEM
    a = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3, entries=[LIN_FLOP])
    b = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3, entries=[LIN_FLOP_MEM])
    merged = merge_profiles([a, b])
    assert sorted(merged.fits) == ["lin_flop", "lin_flop_mem"]
    assert merged.fits["lin_flop"].params == a.fits["lin_flop"].params
    assert merged.fingerprint == device.fingerprint
    assert merged.holdout is not None


def test_merge_identical_fits_are_not_conflicts():
    _, p = _study_profile("citra")
    merged = merge_profiles([p, p])
    assert sorted(merged.fits) == sorted(p.fits)


def test_merge_conflicting_fit_payload_raises():
    device = fleet_device("apex", noise=NOISE)
    a = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3)
    b = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=4)   # new noise draws
    assert a.fits["lin_flop"].params != b.fits["lin_flop"].params
    with pytest.raises(ProfileError, match="conflicting fit"):
        merge_profiles([a, b])


def test_merge_cross_machine_requires_fleet():
    _, a = _study_profile("apex")
    _, b = _study_profile("bulk")
    with pytest.raises(ProfileError, match="different machines"):
        merge_any([a, b])
    merged = merge_any([a, b], allow_cross_machine=True)
    assert len(merged) == 2


def test_fleet_bundle_roundtrip(tmp_path):
    from repro.checkpoint.manager import atomic_write_json
    _, a = _study_profile("apex")
    _, b = _study_profile("bulk")
    path = tmp_path / "fleet.json"
    atomic_write_json(path, fleet_to_dict([a, b]))
    loaded = load_profiles_any(path)
    assert sorted(p.fingerprint.id for p in loaded) \
        == sorted([a.fingerprint.id, b.fingerprint.id])
    for orig in (a, b):
        (match,) = [p for p in loaded
                    if p.fingerprint == orig.fingerprint]
        for name in orig.fits:
            assert match.fits[name].params == orig.fits[name].params
    # a single-profile JSON loads through the same front door
    save_profile(a, tmp_path / "one.json")
    (single,) = load_profiles_any(tmp_path / "one.json")
    assert single.fingerprint == a.fingerprint


# ---------------------------------------------------------------------------
# CLI flows (the CI smoke, in-process)
# ---------------------------------------------------------------------------


def _zoo_args(dev, out, cache_dir, extra=()):
    return ["--smoke", "--zoo", "--synthetic", dev,
            "--synthetic-noise", str(NOISE), "--trials", "2",
            "--cache-dir", str(cache_dir), "--out", str(out), *extra]


def test_cli_two_device_study_compare_merge_happy_path(tmp_path):
    cache = tmp_path / "mc"
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    assert cli_main(_zoo_args("apex", a, cache)) == 0
    assert cli_main(_zoo_args("bulk", b, cache)) == 0

    report_md = tmp_path / "report.md"
    report_json = tmp_path / "report.json"
    assert cli_main(["compare", str(a), str(b),
                     "--report", str(report_md),
                     "--json", str(report_json)]) == 0
    md = report_md.read_text()
    assert "Cross-machine accuracy report" in md
    assert "ovl_flop_mem" in md and "lin_flop" in md
    payload = json.loads(report_json.read_text())
    assert len(payload["machines"]) == 2
    assert sorted(payload["models"]) \
        == ["lin_flop", "lin_flop_mem", "ovl_flop_mem"]
    # every machine has a per-variant error for every model
    for fp in payload["machines"]:
        for m in payload["models"]:
            assert payload["per_variant"][fp][m]
            assert payload["summary"][fp][m] >= 0

    fleet = tmp_path / "fleet.json"
    assert cli_main(["merge", str(a), str(b), "--fleet",
                     "--out", str(fleet)]) == 0
    assert len(load_profiles_any(fleet)) == 2
    # comparing straight from the bundle works too
    assert cli_main(["compare", str(fleet),
                     "--report", str(tmp_path / "r2.md")]) == 0


def test_cli_warm_zoo_study_zero_timings_byte_identical(tmp_path):
    cache = tmp_path / "mc"
    a, a2 = tmp_path / "a.json", tmp_path / "a2.json"
    assert cli_main(_zoo_args("citra", a, cache)) == 0
    assert cli_main(_zoo_args("citra", a2, cache,
                              ["--expect-zero-timings"])) == 0
    assert a.read_text() == a2.read_text()


def test_cli_merge_mismatched_fingerprints_exits_nonzero(tmp_path):
    cache = tmp_path / "mc"
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    assert cli_main(_zoo_args("apex", a, cache)) == 0
    assert cli_main(_zoo_args("bulk", b, cache)) == 0
    assert cli_main(["merge", str(a), str(b),
                     "--out", str(tmp_path / "nope.json")]) == 3
    assert not (tmp_path / "nope.json").exists()
    # duplicate machine in compare is the same class of error
    assert cli_main(["compare", str(a), str(a),
                     "--report", str(tmp_path / "r.md")]) == 3


def test_cli_merge_same_machine_profile(tmp_path):
    device = fleet_device("apex", noise=NOISE)
    from repro.studies import LIN_FLOP, LIN_FLOP_MEM
    a = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3, entries=[LIN_FLOP])
    b = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3, entries=[LIN_FLOP_MEM])
    pa, pb = tmp_path / "a.json", tmp_path / "b.json"
    save_profile(a, pa)
    save_profile(b, pb)
    out = tmp_path / "merged.json"
    assert cli_main(["merge", str(pa), str(pb), "--out", str(out)]) == 0
    assert sorted(load_profile(out).fits) == ["lin_flop", "lin_flop_mem"]


def test_cli_unknown_synthetic_device_is_an_error(tmp_path):
    assert cli_main(["--zoo", "--synthetic", "warp9",
                     "--out", str(tmp_path / "p.json")]) == 2


def test_cli_legacy_single_fit_interface_unchanged(tmp_path):
    """The original flag-style invocation (no subcommand) must keep
    working for real-device calibration scripts."""
    out = tmp_path / "p.json"
    rc = cli_main(["--tags", "empty_kernel", "nelements:16,1024",
                   "--match", "intersect",
                   "--expr", "p_launch * f_sync_launch_kernel",
                   "--trials", "2", "--out", str(out)])
    assert rc == 0
    prof = load_profile(out)
    assert "base" in prof.fits and prof.holdout is None


# ---------------------------------------------------------------------------
# gc subcommand + cache eviction
# ---------------------------------------------------------------------------


FP = DeviceFingerprint(platform="cpu", device_kind="Test CPU", n_devices=1)
OTHER = DeviceFingerprint(platform="cpu", device_kind="Other", n_devices=2)


def _tiny_kernels(n=3):
    import jax.numpy as jnp

    from repro.core.uipick import MeasurementKernel
    kernels = []
    for i in range(n):
        size = 8 * (i + 1)

        def make_args(s=size):
            return (jnp.ones((s,), jnp.float32),)

        kernels.append(MeasurementKernel(
            name=f"tiny_{size}", fn=lambda x: x * 2.0 + 1.0,
            make_args=make_args, tags={"n": size}, sizes={"n": size}))
    return kernels


def _populate(tmp_path, fp, n=2):
    from repro.core.uipick import CountingTimer, gather_feature_table
    cache = MeasurementCache(tmp_path, fp)
    gather_feature_table(["f_wall_time_x", "f_op_float32_mul"],
                         _tiny_kernels(n), trials=4,
                         timer=CountingTimer(lambda k, t: 0.125),
                         cache=cache)
    return cache


def test_gc_drops_foreign_keeps_own_and_warm_gather_unchanged(tmp_path):
    from repro.core.uipick import CountingTimer, gather_feature_table
    _populate(tmp_path, FP, n=3)
    _populate(tmp_path, OTHER, n=2)
    cache = MeasurementCache(tmp_path, FP)
    stats = cache.gc()
    assert stats.kept == 3 and stats.dropped_foreign == 2
    assert stats.dropped == 2
    # warm-gather behavior is unchanged after GC of foreign entries
    timer = CountingTimer(lambda k, t: 0.125)
    gather_feature_table(["f_wall_time_x", "f_op_float32_mul"],
                         _tiny_kernels(3), trials=4, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 0


def test_gc_max_age_drops_old_entries(tmp_path):
    import os
    import time
    _populate(tmp_path, FP, n=2)
    victim = sorted(tmp_path.glob("*.json"))[0]
    old = time.time() - 3600
    os.utime(victim, (old, old))
    stats = MeasurementCache(tmp_path, FP).gc(max_age=600)
    assert stats.dropped_old == 1 and stats.kept == 1


def test_gc_drops_corrupt_entries_but_never_foreign_files(tmp_path):
    """Torn ENTRIES (hash-named) are evicted; files the cache does not own
    (a user's profile saved next to the cache) are never touched."""
    _populate(tmp_path, FP, n=2)
    victim = sorted(p for p in tmp_path.glob("*.json"))[0]
    victim.write_text("{ torn")
    stray = tmp_path / "machine_profile.json"
    stray.write_text('{"valid": "json"}')
    stats = MeasurementCache(tmp_path, FP).gc()
    assert stats.dropped_corrupt == 1 and stats.kept == 1
    assert stray.exists()


def test_gc_drops_stale_schema_entries(tmp_path):
    """Entries written under an older CACHE_SCHEMA_VERSION can never hit
    again (the embedded key mismatches every request) — gc must evict
    them instead of counting them as kept forever."""
    _populate(tmp_path, FP, n=2)
    victim = sorted(tmp_path.glob("*.json"))[0]
    payload = json.loads(victim.read_text())
    payload["key"]["schema"] = -1
    victim.write_text(json.dumps(payload))
    stats = MeasurementCache(tmp_path, FP).gc()
    assert stats.dropped_schema == 1 and stats.kept == 1
    assert stats.dropped == 1


def test_merge_unions_holdout_columns_and_rejects_conflicts():
    """Same-battery studies with different zoo subsets merge their holdout
    tables column-wise; disagreeing row sets or values are conflicts."""
    from repro.core.model import FeatureTable
    import numpy as np

    device = fleet_device("apex", noise=NOISE)
    from repro.studies import LIN_FLOP, LIN_FLOP_MEM
    a = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3, entries=[LIN_FLOP])
    b = run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=3, entries=[LIN_FLOP_MEM])
    merged = merge_profiles([a, b])
    assert merged.holdout.row_names == a.holdout.row_names
    assert set(merged.holdout.feature_ids) \
        == set(a.holdout.feature_ids) | set(b.holdout.feature_ids)

    # disagreeing rows (different battery) → conflict
    c = MachineProfile(
        fingerprint=device.fingerprint, fits=dict(b.fits),
        holdout=FeatureTable(list(b.holdout.feature_ids),
                             b.holdout.values[:1], ["other_kernel"]))
    with pytest.raises(ProfileError, match="held-out splits"):
        merge_profiles([a, c])

    # disagreeing values for a shared column → conflict
    tampered_vals = np.array(a.holdout.values)
    tampered_vals[0, 0] *= 2.0
    d = MachineProfile(
        fingerprint=device.fingerprint, fits={},
        holdout=FeatureTable(list(a.holdout.feature_ids), tampered_vals,
                             list(a.holdout.row_names)))
    with pytest.raises(ProfileError, match="held-out measurements"):
        merge_profiles([a, d])


def test_gc_on_missing_dir_is_a_noop(tmp_path):
    stats = MeasurementCache(tmp_path / "nope", FP).gc()
    assert stats.kept == 0 and stats.dropped == 0


def test_gc_cli(tmp_path):
    local = DeviceFingerprint.local()
    _populate(tmp_path, local, n=2)
    _populate(tmp_path, OTHER, n=1)
    assert cli_main(["gc", "--cache-dir", str(tmp_path)]) == 0
    assert len(MeasurementCache(tmp_path, local)) == 2
