"""The static modelability auditor (``repro.analysis``).

Fixture kernels with KNOWN defects must each draw exactly the diagnostic
class built for that defect — and drawing it must cost abstract traces
only (no kernel execution, no device allocation, no timing):

* scope: unmodeled/opaque primitives, data-dependent while loops,
  mixed precision, runtime-indexed access;
* families: declared FamilySpec degrees checked by exact finite
  differencing over the probe lattice, plus lattice divisibility;
* identifiability: design-matrix rank defects named per parameter;
* signature hazards: callables the count store can never dedup;
* the run_study gate: unidentifiable zoo rungs refuse to fit without
  ``force=True``;
* count-store GC: corrupt > schema > age precedence, foreign files
  untouched.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    AnalysisError,
    Diagnostic,
    DiagnosticReport,
    abstract_args,
    analyze_model,
    audit_callable,
    audit_signature,
    check_lattice,
    load_baseline,
    save_baseline,
    validate_family,
)
from repro.analysis.diagnostics import sort_key
from repro.core.countengine import COUNT_STORE_VERSION, CountEngine
from repro.core.model import Model
from repro.core.uipick import (
    FamilySpec,
    Generator,
    LatticeAssumptionWarning,
    MeasurementKernel,
)

X64 = jax.ShapeDtypeStruct((64,), jnp.float32)


def _codes(diags):
    return sorted({d.code for d in diags})


# ---------------------------------------------------------------------------
# scope auditor
# ---------------------------------------------------------------------------


def test_unmodeled_primitive_is_an_error():
    diags = audit_callable(lambda x: jnp.cumprod(x), (X64,), "kernel:cp")
    assert _codes(diags) == ["unmodeled-primitive"]
    d = diags[0]
    assert d.severity == "error"
    assert d.details["primitive"] == "cumprod"


def test_opaque_primitive_callback_is_an_error():
    def fn(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    diags = audit_callable(fn, (X64,), "kernel:cb")
    assert "opaque-primitive" in _codes(diags)
    assert all(d.severity == "error" for d in diags
               if d.code == "opaque-primitive")


def test_data_dependent_while_is_a_warning():
    def fn(x):
        return jax.lax.while_loop(
            lambda c: c[1] < 5, lambda c: (c[0] * 1.5, c[1] + 1), (x, 0))[0]

    diags = audit_callable(fn, (X64,), "kernel:wh")
    assert _codes(diags) == ["while-trip-count"]
    assert diags[0].severity == "warning"


def test_mixed_precision_is_a_warning_naming_both_dtypes():
    def fn(x):
        return (x.astype(jnp.bfloat16) * 2).astype(jnp.float32) + x * 3

    diags = audit_callable(fn, (X64,), "kernel:mp")
    assert _codes(diags) == ["mixed-precision"]
    assert diags[0].details["dtypes"] == ["bfloat16", "float32"]


def test_runtime_indexing_is_an_info():
    def fn(x):
        return jnp.take(x, jnp.zeros((4,), jnp.int32))

    diags = audit_callable(fn, (X64,), "kernel:tk")
    assert _codes(diags) == ["data-dependent-access"]
    assert diags[0].severity == "info"


def test_untraceable_kernel_is_reported_not_raised():
    stats = {"traces": 0}
    diags = audit_callable(lambda x: x.no_such_attr(), (X64,),
                           "kernel:boom", stats=stats)
    assert _codes(diags) == ["untraceable-kernel"]
    assert stats["traces"] == 1     # the failed attempt still counts


def test_clean_kernel_draws_nothing():
    assert audit_callable(lambda x: jnp.tanh(x) + 1.0, (X64,),
                          "kernel:ok") == []


def test_abstract_args_never_materializes_the_arrays():
    """The builder below would allocate 4 TiB if it ever ran concretely;
    eval_shape hands back pure shape/dtype structs instead."""
    def make_args():
        return (jnp.zeros((1 << 20, 1 << 20), jnp.float32),)

    (a,) = abstract_args(make_args)
    assert a.shape == (1 << 20, 1 << 20) and a.dtype == jnp.float32
    assert audit_callable(lambda x: x * 2.0, (a,), "kernel:huge") == []


# ---------------------------------------------------------------------------
# family validator
# ---------------------------------------------------------------------------


def _fixture_kernel(n, shape):
    def fn(x):
        return x * 2.0

    def make_args():
        return (jnp.ones(shape, jnp.float32),)

    return MeasurementKernel(name=f"fx_{n}", fn=fn, make_args=make_args,
                             tags={}, sizes={"n": n})


def _fixture_gen(shape_of, degree, sizes=(16, 32)):
    return Generator("fixture", frozenset({"fx"}),
                     arg_space=dict(n=tuple(sizes)),
                     build=lambda *, n: _fixture_kernel(n, shape_of(n)),
                     family=FamilySpec(var_degrees={"n": degree}))


def test_family_degree_mismatch_quadratic_declared_linear():
    gen = _fixture_gen(lambda n: (n, n), degree=1)
    stats = {"traces": 0}
    diags = validate_family(gen, stats=stats)
    assert "family-degree-mismatch" in _codes(diags)
    d = next(d for d in diags if d.code == "family-degree-mismatch")
    assert d.severity == "error"
    assert d.details["declared_degree"] == 1
    assert d.details["actual_degree"] == 2
    assert stats["traces"] == 4     # d+3 lattice points, memoized


def test_family_non_polynomial_log_factor():
    # element count n·bit_length(n): no polynomial of any degree fits the
    # lattice, so Δ^{d+1} is non-constant
    gen = _fixture_gen(lambda n: (n * int(n).bit_length(),), degree=1)
    diags = validate_family(gen)
    assert "family-non-polynomial" in _codes(diags)
    d = next(d for d in diags if d.code == "family-non-polynomial")
    assert d.severity == "error"
    assert d.details["lattice"] == [16, 32, 48, 64]


def test_family_degree_overdeclared_is_an_info():
    gen = _fixture_gen(lambda n: (n,), degree=2)
    diags = validate_family(gen)
    assert _codes(diags) == ["family-degree-overdeclared"]
    assert diags[0].severity == "info"


def test_family_correct_degree_is_silent():
    assert validate_family(_fixture_gen(lambda n: (n,), degree=1)) == []
    assert validate_family(_fixture_gen(lambda n: (n, n), degree=2)) == []


def test_family_validator_skips_familyless_generators():
    gen = Generator("plain", frozenset({"p"}), arg_space=dict(n=(16,)),
                    build=lambda *, n: _fixture_kernel(n, (n,)))
    assert validate_family(gen) == []
    assert check_lattice(gen) == []


def test_check_lattice_flags_off_lattice_argument_sizes():
    gen = _fixture_gen(lambda n: (n,), degree=1, sizes=(16, 20, 32))
    diags = check_lattice(gen)
    assert _codes(diags) == ["probe-lattice-divisibility"]
    assert diags[0].severity == "warning"
    assert diags[0].details == {"variable": "n", "sizes": [20], "scale": 16}


def test_generation_time_lattice_warning_matches_static_diagnostic():
    """The runtime twin: actually generating the off-lattice variant warns
    LatticeAssumptionWarning once."""
    gen = _fixture_gen(lambda n: (n,), degree=1, sizes=(16, 20))
    with pytest.warns(LatticeAssumptionWarning):
        kernels = list(gen.variants({}))
    assert len(kernels) == 2


# ---------------------------------------------------------------------------
# identifiability analyzer
# ---------------------------------------------------------------------------


def test_collinear_parameters_named_with_shared_features():
    m = Model("f_t", "p_a * f_x + p_b * f_x")
    F = m.align([{"f_x": 1.0}, {"f_x": 2.0}, {"f_x": 3.0}], missing="zero")
    diags = analyze_model(m, F, "model:twin")
    # the pairwise diagnostic names the defect; the generic rank-defect
    # diagnostic must NOT double-report the same pair
    assert _codes(diags) == ["collinear-parameters"]
    d = diags[0]
    assert d.details["params"] == ["p_a", "p_b"]
    assert d.details["features"] == {"p_a": ["f_x"], "p_b": ["f_x"]}


def test_unexercised_parameter_names_its_features():
    m = Model("f_t", "p_a * f_x + p_b * f_y")
    F = m.align([{"f_x": 1.0}, {"f_x": 2.0}], missing="zero")
    diags = analyze_model(m, F, "model:dead")
    assert _codes(diags) == ["unexercised-parameter"]
    assert diags[0].details == {"param": "p_b", "features": ["f_y"]}


def test_underdetermined_battery_fewer_rows_than_params():
    m = Model("f_t", "p_a * f_x + p_b * f_y")
    F = m.align([{"f_x": 1.0, "f_y": 2.0}], missing="zero")
    diags = analyze_model(m, F, "model:thin")
    assert _codes(diags) == ["underdetermined-battery"]
    assert diags[0].details["rows"] == 1


def test_ill_conditioned_fit_full_rank_but_wobbly():
    eps = 1e-6
    m = Model("f_t", "p_a * f_x + p_b * f_y + p_c * f_z")
    rows = [{"f_x": 1.0, "f_y": 0.0, "f_z": 1.0 + eps},
            {"f_x": 0.0, "f_y": 1.0, "f_z": 1.0 + eps},
            {"f_x": 1.0, "f_y": 1.0, "f_z": 2.0 - eps}]
    diags = analyze_model(m, m.align(rows, missing="zero"), "model:wob")
    assert _codes(diags) == ["ill-conditioned-fit"]
    assert diags[0].severity == "warning"
    assert diags[0].details["condition_number"] > 1e6


def test_well_posed_battery_is_silent():
    m = Model("f_t", "p_a * f_x + p_b * f_y")
    rows = [{"f_x": 1.0, "f_y": 0.0}, {"f_x": 0.0, "f_y": 1.0},
            {"f_x": 2.0, "f_y": 3.0}]
    assert analyze_model(m, m.align(rows, missing="zero"), "model:ok") == []


def test_run_study_refuses_unidentifiable_rung_unless_forced():
    from repro.studies import STUDY_SMOKE_TAGS, StudyError, run_study
    from repro.studies.zoo import ZooEntry
    from repro.testing.synthdev import fleet_device

    device = fleet_device("citra", noise=0.0)
    twin = ZooEntry(
        name="twin_madd", scope_rank=0,
        expr="p_a * f_op_float32_madd + p_b * f_op_float32_madd "
             "+ p_launch * f_sync_launch_kernel")
    with pytest.raises(StudyError, match="collinear-parameters"):
        run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=STUDY_SMOKE_TAGS, trials=2, entries=[twin])
    profile = run_study(fingerprint=device.fingerprint, timer=device.timer,
                        tags=STUDY_SMOKE_TAGS, trials=2, entries=[twin],
                        force=True)
    assert "twin_madd" in profile.fits


# ---------------------------------------------------------------------------
# cache-signature hazards
# ---------------------------------------------------------------------------


def test_sourceless_callable_is_unsignable():
    ns = {}
    exec("def nosrc(x):\n    return x * 2.0", ns)
    diags = audit_signature(ns["nosrc"], "kernel:nosrc")
    assert _codes(diags) == ["unsignable-callable"]
    assert diags[0].severity == "warning"
    assert any("source" in r for r in diags[0].details["reasons"])


def test_mutable_captured_state_is_an_info():
    cfg = {"k": 2.0}

    def kern(x, opts=[1.0]):            # noqa: B006 — the defect under test
        return x * cfg["k"] * opts[0]

    diags = audit_signature(kern, "kernel:mut")
    assert "mutable-captured-state" in _codes(diags)
    d = next(d for d in diags if d.code == "mutable-captured-state")
    assert d.details["names"] == ["cfg", "opts"]


def test_plain_closure_over_scalars_is_clean():
    c = 3.0

    def kern(x):
        return x * c

    assert audit_signature(kern, "kernel:ok") == []


# ---------------------------------------------------------------------------
# diagnostics: ordering, suppression, baseline
# ---------------------------------------------------------------------------


def _diag(sev, code, loc, msg="m"):
    return Diagnostic(sev, code, loc, msg)


def test_report_sorts_by_severity_then_location_then_code():
    report = DiagnosticReport()
    report.extend([
        _diag("info", "c", "z"),
        _diag("error", "b", "kernel:b"),
        _diag("warning", "a", "kernel:a"),
        _diag("error", "a", "kernel:b"),
        _diag("error", "a", "kernel:a"),
    ])
    got = [(d.severity, d.location, d.code) for d in report.sorted()]
    assert got == [("error", "kernel:a", "a"), ("error", "kernel:b", "a"),
                   ("error", "kernel:b", "b"), ("warning", "kernel:a", "a"),
                   ("info", "z", "c")]
    assert got == [(d.severity, d.location, d.code)
                   for d in sorted(report.diagnostics, key=sort_key)]


def test_invalid_severity_is_rejected():
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("fatal", "c", "l", "m")


def test_suppress_by_code_and_by_key():
    report = DiagnosticReport()
    report.extend([_diag("error", "a", "k:1"), _diag("error", "a", "k:2"),
                   _diag("error", "b", "k:1")])
    by_code = report.suppress(["a"])
    assert [d.code for d in by_code.diagnostics] == ["b"]
    assert len(by_code.suppressed) == 2
    by_key = report.suppress(["a@k:1"])
    assert sorted(d.key for d in by_key.diagnostics) == ["a@k:2", "b@k:1"]
    # suppressed findings never fail the run
    assert by_code.new_errors([]) == by_code.diagnostics


def test_baseline_round_trip_and_regression(tmp_path):
    report = DiagnosticReport()
    report.extend([_diag("error", "a", "k:1"), _diag("warning", "w", "k:1")])
    path = tmp_path / "baseline.json"
    save_baseline(report, path)
    assert load_baseline(path) == ["a@k:1"]     # warnings never baseline
    assert report.new_errors(load_baseline(path)) == []
    report.extend([_diag("error", "a", "k:2")])
    assert [d.key for d in report.new_errors(load_baseline(path))] \
        == ["a@k:2"]


def test_malformed_baseline_is_a_typed_error(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("[1, 2, 3]")
    with pytest.raises(AnalysisError, match="lint baseline"):
        load_baseline(bad)
    with pytest.raises(AnalysisError, match="cannot read"):
        load_baseline(tmp_path / "missing.json")


# ---------------------------------------------------------------------------
# count-store GC
# ---------------------------------------------------------------------------


def _stream_kernel(c):
    def fn(x):
        return x * c

    return fn


def _seed_entries(store, n):
    eng = CountEngine(store=store)
    for i in range(n):
        eng.counts_of_callable(_stream_kernel(float(i + 1)),
                               (jnp.ones((8,), jnp.float32),))
    files = sorted((store / "counts").glob("*.json"))
    assert len(files) == n
    return files


def test_gc_precedence_corrupt_then_schema_then_age(tmp_path):
    keep, corrupt, schema, old = _seed_entries(tmp_path, 4)
    # corrupt AND ancient: corrupt wins (precedence)
    corrupt.write_text("not json at all")
    os.utime(corrupt, (1, 1))
    payload = json.loads(schema.read_text())
    payload["version"] = COUNT_STORE_VERSION - 1
    schema.write_text(json.dumps(payload))
    os.utime(old, (1, 1))
    # a foreign file is never ours to delete
    stranger = tmp_path / "counts" / "README.json"
    stranger.write_text("{}")

    stats = CountEngine(store=tmp_path).gc(max_age=3600.0)
    assert (stats.kept, stats.dropped_corrupt, stats.dropped_schema,
            stats.dropped_old) == (1, 1, 1, 1)
    assert stats.dropped == 3
    assert keep.exists() and stranger.exists()
    assert not corrupt.exists() and not schema.exists() and not old.exists()


def test_gc_drops_entries_whose_key_disagrees_with_filename(tmp_path):
    (entry,) = _seed_entries(tmp_path, 1)
    miscopied = entry.with_name("0" * 64 + ".json")
    miscopied.write_text(entry.read_text())
    stats = CountEngine(store=tmp_path).gc()
    assert stats.kept == 1 and stats.dropped_corrupt == 1
    assert entry.exists() and not miscopied.exists()


def test_gc_without_max_age_keeps_valid_entries(tmp_path):
    files = _seed_entries(tmp_path, 2)
    for f in files:
        os.utime(f, (1, 1))
    stats = CountEngine(store=tmp_path).gc()
    assert stats.kept == 2 and stats.dropped == 0
    stats = CountEngine(store=tmp_path).gc(max_age=3600.0)
    assert stats.kept == 0 and stats.dropped_old == 2


def test_gc_on_storeless_engine_is_a_noop():
    stats = CountEngine().gc(max_age=0.0)
    assert stats.kept == 0 and stats.dropped == 0


# ---------------------------------------------------------------------------
# the session facade's audit
# ---------------------------------------------------------------------------


def test_session_audit_flags_out_of_scope_and_unmodeled(tmp_path):
    from repro.api import PerfSession
    from repro.core.calibrate import FitResult
    from repro.profiles import DeviceFingerprint, MachineProfile, ModelFit

    model = Model("f_wall_time_cpu_host",
                  "p_madd * f_op_float32_madd "
                  "+ p_launch * f_sync_launch_kernel")
    fit = FitResult(params={"p_madd": 1e-10, "p_launch": 1e-6},
                    residual_norm=0.0, iterations=1, converged=True)
    profile = MachineProfile(
        fingerprint=DeviceFingerprint(platform="synth",
                                      device_kind="audit-test", n_devices=1),
        fits={"lin": ModelFit.from_fit(model, fit)}, trials=2)
    session = PerfSession.open(profile)

    abstract = (jax.ShapeDtypeStruct((32,), jnp.float32),)
    report = session.audit([
        (lambda x: jnp.tanh(x) * 2.0, abstract),    # transc: out of scope
        (lambda x: jnp.cumprod(x), abstract),       # unmodeled primitive
    ])
    codes = report.codes()
    assert "out-of-scope-feature" in codes
    assert "unmodeled-primitive" in codes
    assert report.stats["timings"] == 0
    assert report.stats["traces"] >= 2
