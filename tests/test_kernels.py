"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rn(*shape, dtype=jnp.float32, i=0):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape,
                             jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 128, 512, 128, 128, 64),
    (512, 512, 256, 256, 128, 256),
])
def test_matmul_tiled(dtype, m, k, n, bm, bn, bk):
    a, b = rn(m, k, dtype=dtype, i=1), rn(k, n, dtype=dtype, i=2)
    _close(ops.matmul(a, b, block_m=bm, block_n=bn, block_k=bk),
           ref.matmul_ref(a, b), dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kw", [
    dict(causal=True),
    dict(causal=False),
    dict(causal=True, window=64),
    dict(causal=True, softcap=30.0),
    dict(causal=True, window=32, softcap=50.0),
])
@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (2, 256, 8, 2, 64),    # GQA 4:1
    (1, 128, 4, 4, 128),   # MHA
    (2, 512, 8, 1, 64),    # MQA
])
def test_flash_attention(dtype, kw, B, S, Hq, Hkv, D):
    q = rn(B, S, Hq, D, dtype=dtype, i=3)
    k = rn(B, S, Hkv, D, dtype=dtype, i=4)
    v = rn(B, S, Hkv, D, dtype=dtype, i=5)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    _close(out, ref.attention_ref(q, k, v, **kw), dtype)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),
    (2, 64, 8, 16, 32, 16),
])
def test_mamba2_ssd(B, S, H, P, N, chunk):
    xdt = rn(B, S, H, P, i=6)
    da = -jnp.abs(rn(B, S, H, i=7)) * 0.1
    Bm, Cm = rn(B, S, H, N, i=8), rn(B, S, H, N, i=9)
    out = ops.mamba2_ssd(xdt, da, Bm, Cm, chunk=chunk)
    _close(out, ref.ssd_ref(xdt, da, Bm, Cm), jnp.float32)


@pytest.mark.parametrize("m,n,bm,bn", [
    (256, 256, 128, 128), (256, 512, 256, 256), (128, 128, 64, 128)])
def test_stencil5(m, n, bm, bn):
    u = rn(m, n, i=10)
    _close(ops.stencil5(u, block_m=bm, block_n=bn), ref.stencil5_ref(u),
           jnp.float32)


@pytest.mark.parametrize("M,N,K,be", [(3, 64, 1024, 256), (1, 32, 512, 512)])
def test_dg_diff(M, N, K, be):
    dm, ut = rn(M, N, N, i=11), rn(N, K, i=12)
    _close(ops.dg_diff(dm, ut, block_e=be), ref.dg_diff_ref(dm, ut),
           jnp.float32)


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("n_arrays", [1, 3])
def test_stream_strided(stride, n_arrays):
    arrs = [rn(8192, i=20 + j) for j in range(n_arrays)]
    _close(ops.stream_strided(arrs, block=256, stride=stride),
           ref.stream_ref(arrs, block=256, stride=stride), jnp.float32)


def test_madd_throughput():
    x = rn(4096, i=30)
    _close(ops.madd_throughput(x, iters=32, block=1024),
           ref.madd_ref(x, iters=32), jnp.float32)


def test_flash_vs_model_blockwise():
    """The Pallas kernel and the model library's jnp blockwise path are the
    same contraction — they must agree bitwise-closely."""
    from repro.models.layers import blockwise_attention

    q, k, v = rn(2, 256, 8, 64, i=40), rn(2, 256, 2, 64, i=41), \
        rn(2, 256, 2, 64, i=42)
    a = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    b = blockwise_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-5)


@pytest.mark.parametrize("B,S,H,dh", [(2, 24, 4, 16), (1, 48, 2, 32)])
def test_slstm_cell_kernel(B, S, H, dh):
    g_in = rn(B, S, 4, H, dh, i=50) * 0.5
    r = rn(H, dh, 4, dh, i=51) * 0.1
    b = rn(4, H, dh, i=52) * 0.1
    out = ops.slstm_cell(g_in, r, b)
    want = ref.slstm_cell_ref(g_in, r, b)
    _close(out, want, jnp.float32)
