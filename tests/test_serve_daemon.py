"""The prediction-serving daemon: coalescing, thread safety, multi-tenant
LRU, and the HTTP surface.

Serving is the steady state the whole pipeline exists for, and its three
guarantees are asserted here through the same observability probes the
CLI smoke uses:

* **zero timings** — prediction never executes a kernel, no matter how
  many threads hammer the daemon (``session.timer.calls == 0``);
* **coalescing** — K concurrent requests collapse into ONE compiled
  ``batched_breakdown`` evaluation (``session.eval_calls``) and at most
  one count lookup per unique kernel;
* **consistency under races** — the count engine's counters balance
  (hits + misses == lookups), a cold kernel raced by N threads is traced
  exactly once, and the persisted count store written under contention
  is byte-identical to one written serially.
"""
from __future__ import annotations

import json
import threading
import time
import urllib.error
import urllib.request
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax.numpy as jnp
import pytest

from repro.api import PerfSession, Prediction, PredictionError
from repro.core.calibrate import FitResult
from repro.core.countengine import CountEngine
from repro.profiles import DeviceFingerprint, MachineProfile, ModelFit
from repro.serving import (
    BatcherClosed,
    CoalescingBatcher,
    PredictionDaemon,
    SessionPool,
)
from repro.studies.zoo import OVL_FLOP_MEM

N_UNIQUE = 8


def _profile() -> MachineProfile:
    model = OVL_FLOP_MEM.model()
    fit = FitResult(params={"p_madd": 5e-11, "p_mem": 4e-10,
                            "p_launch": 3e-6, "p_edge": 40.0},
                    residual_norm=0.0, iterations=1, converged=True)
    return MachineProfile(
        fingerprint=DeviceFingerprint(platform="synth",
                                      device_kind="serve-test",
                                      n_devices=1),
        fits={OVL_FLOP_MEM.name: ModelFit.from_fit(model, fit)},
        trials=3)


def _targets(n: int = N_UNIQUE):
    """n unique in-scope (fn, args) predict items (adds + contiguous
    memory — fully inside the ovl_flop_mem model's scope)."""
    out = {}
    for i in range(n):
        size = 32 * (i + 1)
        out[f"t{i}"] = ((lambda x: x + 1.0),
                        (jnp.ones((size,), jnp.float32),))
    return out


def _session(**kw) -> PerfSession:
    return PerfSession.open(_profile(), **kw)


# ---------------------------------------------------------------------------
# CountEngine under contention
# ---------------------------------------------------------------------------


def test_cold_race_traces_each_kernel_exactly_once():
    engine = CountEngine()
    targets = list(_targets().values())
    n_threads = 16
    barrier = threading.Barrier(n_threads)

    def hammer(tid: int):
        barrier.wait()      # maximal contention on the cold path
        for i in range(len(targets) * 4):
            fn, args = targets[(tid + i) % len(targets)]
            c = engine.counts_of_callable(fn, args)
            assert c["f_op_float32_add"] == args[0].shape[0]

    with ThreadPoolExecutor(max_workers=n_threads) as pool:
        for f in [pool.submit(hammer, t) for t in range(n_threads)]:
            f.result(timeout=60)

    stats = engine.stats()
    # two threads racing one cold kernel perform exactly ONE trace
    assert stats["trace_count"] == N_UNIQUE
    assert stats["misses"] == N_UNIQUE
    lookups = n_threads * len(targets) * 4
    assert stats["hits"] + stats["misses"] == lookups


def _store_bytes(store: Path) -> dict:
    return {p.relative_to(store).as_posix(): p.read_bytes()
            for p in sorted(store.rglob("*")) if p.is_file()}


def test_contended_store_is_byte_identical_to_serial(tmp_path):
    targets = list(_targets().values())

    serial = CountEngine(store=tmp_path / "serial")
    for fn, args in targets:
        serial.counts_of_callable(fn, args)

    racy = CountEngine(store=tmp_path / "racy")
    with ThreadPoolExecutor(max_workers=16) as pool:
        futs = [pool.submit(racy.counts_of_callable, fn, args)
                for _ in range(8) for fn, args in targets]
        for f in futs:
            f.result(timeout=60)

    assert _store_bytes(tmp_path / "racy") \
        == _store_bytes(tmp_path / "serial")

    # a THIRD engine reading the racy store serves all counts traceless
    warm = CountEngine(store=tmp_path / "racy")
    for fn, args in targets:
        warm.counts_of_callable(fn, args)
    assert warm.trace_count == 0


def test_threaded_predict_zero_traces_and_timings_after_warmup(tmp_path):
    session = _session(engine=CountEngine(store=tmp_path / "store"))
    targets = list(_targets().values())
    session.predict_batch(targets)                      # warmup
    traces0 = session.engine.trace_count

    def burst(tid: int):
        fn, args = targets[tid % len(targets)]
        return session.predict(fn, *args)

    with ThreadPoolExecutor(max_workers=12) as pool:
        preds = [f.result(timeout=60)
                 for f in [pool.submit(burst, t) for t in range(24)]]

    assert all(isinstance(p, Prediction) and p.seconds > 0 for p in preds)
    assert session.engine.trace_count == traces0        # all warm
    assert session.timer.calls == 0
    stats = session.engine.stats()
    assert stats["hits"] + stats["misses"] \
        == len(targets) + 24                            # balanced ledger


# ---------------------------------------------------------------------------
# coalescing
# ---------------------------------------------------------------------------


def test_concurrent_requests_coalesce_into_one_compiled_eval():
    session = _session()
    batcher = CoalescingBatcher(session, max_wait_s=0.001)
    try:
        batcher.hold()
        futs = [batcher.submit(item, name=name)
                for name, item in _targets().items()
                for _ in range(4)]                      # 32 requests
        assert batcher.pending_count() == 32
        batcher.release()
        preds = [f.result(timeout=60) for f in futs]
        assert all(p.seconds > 0 for p in preds)
        # ONE drained batch → ONE batched_breakdown dispatch, and dedup
        # kept count lookups at one per unique kernel
        assert session.eval_calls == 1
        eng = session.engine
        assert eng.hits + eng.misses == N_UNIQUE
        assert batcher.stats()["batches"] == 1
        assert batcher.stats()["max_batch_size"] == 32
    finally:
        batcher.close()


def test_batcher_maps_per_item_errors_to_the_right_caller():
    session = _session()
    batcher = CoalescingBatcher(session, max_wait_s=0.001)
    try:
        batcher.hold()
        good = batcher.submit((lambda x: x + 1.0,
                               (jnp.ones((64,), jnp.float32),)),
                              name="good", strict=True)
        bad = batcher.submit((lambda x: jnp.exp(x),
                              (jnp.ones((64,), jnp.float32),)),
                             name="bad", strict=True)
        batcher.release()
        # the in-scope batch-mate is unaffected...
        assert good.result(timeout=60).seconds > 0
        # ...while the out-of-scope item gets its OWN typed error
        with pytest.raises(PredictionError) as exc:
            bad.result(timeout=60)
        (v,) = exc.value.violations
        assert v["kernel"] == "bad"
        assert "f_op_float32_transc" in v["features"]
        # and the mixed batch still cost one compiled evaluation
        assert session.eval_calls == 1
    finally:
        batcher.close()


def test_closed_batcher_rejects_submits_but_drains_queue():
    session = _session()
    batcher = CoalescingBatcher(session, max_wait_s=0.001)
    batcher.hold()
    fut = batcher.submit((lambda x: x + 1.0,
                          (jnp.ones((32,), jnp.float32),)))
    batcher.close()                     # queued work drains before exit
    assert fut.result(timeout=60).seconds > 0
    with pytest.raises(BatcherClosed):
        batcher.submit((lambda x: x + 1.0,
                        (jnp.ones((32,), jnp.float32),)))


def test_strict_batch_collects_every_violation():
    session = _session()
    with pytest.raises(PredictionError) as exc:
        session.predict_batch(
            [(lambda x: x + 1.0, (jnp.ones((32,), jnp.float32),)),
             (lambda x: jnp.exp(x), (jnp.ones((32,), jnp.float32),)),
             (lambda x: jnp.sin(x), (jnp.ones((64,), jnp.float32),))],
            names=["ok", "bad_exp", "bad_sin"], strict=True)
    vs = exc.value.violations
    # BOTH offenders reported in one error, mapped to their indices
    assert [(v["index"], v["kernel"]) for v in vs] \
        == [(1, "bad_exp"), (2, "bad_sin")]
    assert all("f_op_float32_transc" in v["features"] for v in vs)
    assert "bad_exp" in str(exc.value) and "bad_sin" in str(exc.value)


# ---------------------------------------------------------------------------
# the LRU session pool
# ---------------------------------------------------------------------------


def test_session_pool_lru_eviction_and_reopen(tmp_path):
    opened = []

    def factory(path, *, cache=None):
        opened.append(path)
        return _session()

    pool = SessionPool(max_open=2, session_factory=factory)
    try:
        s1, b1 = pool.get("p1")
        s2, _ = pool.get("p2")
        assert pool.get("p1") == (s1, b1)               # LRU refresh: hit
        pool.get("p3")                                  # evicts p2 (LRU)
        assert pool.stats() == {"open": 2, "opens": 3, "hits": 1,
                                "evictions": 1}
        s2b, _ = pool.get("p2")                         # reopen evicts p1
        assert s2b is not s2
        assert opened == ["p1", "p2", "p3", "p2"]
        # the evicted entry's batcher was closed on the way out
        with pytest.raises(BatcherClosed):
            b1.submit((lambda x: x + 1.0,
                       (jnp.ones((16,), jnp.float32),)))
    finally:
        pool.close()


def test_session_pool_serves_through_fresh_batcher_after_eviction():
    def factory(path, *, cache=None):
        return _session()

    pool = SessionPool(max_open=1, session_factory=factory,
                       max_wait_s=0.001)
    try:
        _, b1 = pool.get("p1")
        _, b2 = pool.get("p2")                          # evicts + closes b1
        with pytest.raises(BatcherClosed):
            b1.submit((lambda x: x + 1.0,
                       (jnp.ones((16,), jnp.float32),)))
        pred = b2.predict((lambda x: x + 1.0,
                           (jnp.ones((16,), jnp.float32),)),
                          timeout=60)
        assert pred.seconds > 0
        assert pool.stats()["evictions"] == 1
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# the HTTP daemon
# ---------------------------------------------------------------------------


@pytest.fixture
def daemon():
    d = PredictionDaemon(_session(), port=0, targets=_targets(4),
                         max_wait_s=0.001).start()
    yield d
    d.close()


def _post(url: str, body: dict, timeout: float = 60.0):
    req = urllib.request.Request(
        url, data=json.dumps(body).encode(), method="POST",
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_daemon_serves_concurrent_burst_with_one_eval(daemon):
    burst = 16
    daemon.batcher.hold()
    with ThreadPoolExecutor(max_workers=burst) as pool:
        futs = [pool.submit(_post, f"{daemon.url}/predict",
                            {"kernel": f"t{i % 4}"})
                for i in range(burst)]
        deadline = time.monotonic() + 30.0
        while daemon.batcher.pending_count() < burst:
            assert time.monotonic() < deadline, \
                f"only {daemon.batcher.pending_count()}/{burst} parked"
            time.sleep(0.005)
        daemon.batcher.release()
        replies = [f.result(timeout=60) for f in futs]

    assert all(status == 200 for status, _ in replies)
    assert all(body["seconds"] > 0 and body["model"] == "ovl_flop_mem"
               for _, body in replies)
    stats = daemon.stats()
    assert stats["timings"] == 0
    assert stats["eval_calls"] == 1
    assert stats["count_lookups"] <= 4
    assert stats["batcher"]["max_batch_size"] == burst


def test_daemon_http_error_codes(daemon):
    status, body = _post(f"{daemon.url}/predict", {"kernel": "nope"})
    assert status == 404 and "t0" in body["known"]
    status, body = _post(f"{daemon.url}/predict", {})
    assert status == 400
    # strict + out-of-scope → 422 carrying the violation record
    daemon.targets["exp"] = ((lambda x: jnp.exp(x)),
                             (jnp.ones((64,), jnp.float32),))
    status, body = _post(f"{daemon.url}/predict",
                         {"kernel": "exp", "strict": True})
    assert status == 422
    (v,) = body["violations"]
    assert v["features"] == ["f_op_float32_transc"]


def test_daemon_stats_and_shutdown_routes(daemon):
    with urllib.request.urlopen(f"{daemon.url}/healthz", timeout=30) as r:
        assert json.loads(r.read()) == {"ok": True}
    _post(f"{daemon.url}/predict", {"kernel": "t0"})
    with urllib.request.urlopen(f"{daemon.url}/stats", timeout=30) as r:
        stats = json.loads(r.read())
    assert stats["timings"] == 0 and stats["batcher"]["requests"] == 1
    status, body = _post(f"{daemon.url}/shutdown", {})
    assert status == 200 and body == {"ok": True}
    # the listener actually stopped
    deadline = time.monotonic() + 10.0
    while time.monotonic() < deadline:
        try:
            urllib.request.urlopen(f"{daemon.url}/healthz", timeout=1)
            time.sleep(0.02)
        except (urllib.error.URLError, ConnectionError, OSError):
            break
    else:
        pytest.fail("daemon kept answering after /shutdown")
