"""One-pass gather semantics + incremental measurement cache + CLI.

Regression battery for the calibration-pipeline sweep: each kernel is timed
exactly once per gather regardless of wall-time column count, warm cache
runs perform zero timings, and the cache invalidates on fingerprint/trials
changes."""
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.uipick import CountingTimer, MeasurementKernel, \
    TimingStats, gather_feature_table
from repro.profiles import DeviceFingerprint, MeasurementCache
from repro.profiles.cli import main as calibrate_main

FP = DeviceFingerprint(platform="cpu", device_kind="Test CPU", n_devices=1)
OTHER_FP = DeviceFingerprint(platform="cpu", device_kind="Other CPU",
                             n_devices=2)


def _tiny_kernels(n=3):
    kernels = []
    for i in range(n):
        size = 8 * (i + 1)

        def make_args(s=size):
            return (jnp.ones((s,), jnp.float32),)

        kernels.append(MeasurementKernel(
            name=f"tiny_{size}", fn=lambda x: x * 2.0 + 1.0,
            make_args=make_args, tags={"n": size}, sizes={"n": size}))
    return kernels


def _fake_timer():
    return CountingTimer(lambda k, trials: 0.125)


FEATURES = ["f_wall_time_cpu_host", "f_op_float32_mul", "f_op_float32_add"]


def test_multiple_wall_time_columns_time_each_kernel_once():
    """k wall-time columns must NOT mean k timing passes (the original
    per-column loop re-ran the full measurement per column)."""
    kernels = _tiny_kernels(3)
    timer = _fake_timer()
    features = ["f_wall_time_a", "f_wall_time_b", "f_wall_time_c",
                "f_op_float32_mul"]
    table = gather_feature_table(features, kernels, trials=4, timer=timer)
    assert timer.calls == len(kernels)          # exactly one pass per kernel
    vals = table.values
    np.testing.assert_array_equal(vals[:, 0], vals[:, 1])
    np.testing.assert_array_equal(vals[:, 0], vals[:, 2])
    assert list(vals[:, 3]) == [8.0, 16.0, 24.0]


def test_counts_only_gather_never_times():
    kernels = _tiny_kernels(2)
    timer = _fake_timer()
    gather_feature_table(["f_op_float32_mul"], kernels, timer=timer)
    assert timer.calls == 0


def test_warm_cache_performs_zero_timings(tmp_path):
    kernels = _tiny_kernels(3)
    cache = MeasurementCache(tmp_path, FP)
    cold = _fake_timer()
    t1 = gather_feature_table(FEATURES, kernels, trials=4, timer=cold,
                              cache=cache)
    assert cold.calls == 3 and cache.misses == 3 and cache.hits == 0

    warm_cache = MeasurementCache(tmp_path, FP)
    warm = _fake_timer()
    # fresh kernel objects: nothing memoized in-process
    t2 = gather_feature_table(FEATURES, _tiny_kernels(3), trials=4,
                              timer=warm, cache=warm_cache)
    assert warm.calls == 0 and warm_cache.hits == 3
    np.testing.assert_array_equal(t1.values, t2.values)
    assert t1.feature_ids == t2.feature_ids


def test_cache_incremental_only_new_kernels_timed(tmp_path):
    cache = MeasurementCache(tmp_path, FP)
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                         timer=_fake_timer(), cache=cache)
    timer = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(4), trials=4, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 2                     # only the two new kernels


def test_cache_invalidates_on_trials_change(tmp_path):
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                         timer=_fake_timer(),
                         cache=MeasurementCache(tmp_path, FP))
    timer = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=8, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 2


def test_cache_invalidates_on_fingerprint_change(tmp_path):
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                         timer=_fake_timer(),
                         cache=MeasurementCache(tmp_path, FP))
    timer = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4, timer=timer,
                         cache=MeasurementCache(tmp_path, OTHER_FP))
    assert timer.calls == 2


def test_corrupt_cache_entry_is_a_miss_and_heals(tmp_path):
    cache = MeasurementCache(tmp_path, FP)
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                         timer=_fake_timer(), cache=cache)
    victim = sorted(tmp_path.glob("*.json"))[0]
    victim.write_text("{ torn write")
    timer = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 1                     # only the corrupted entry
    # healed: fully warm again
    timer2 = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4, timer=timer2,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer2.calls == 0


@pytest.mark.parametrize("junk", ["null", "[]", "42",
                                  '{"key": {}, "counts": "nope"}'])
def test_valid_json_but_wrong_shape_entry_is_a_miss(tmp_path, junk):
    """Entries that parse as JSON but aren't well-formed cache objects must
    read as misses, not crash the gather."""
    cache = MeasurementCache(tmp_path, FP)
    gather_feature_table(FEATURES, _tiny_kernels(1), trials=4,
                         timer=_fake_timer(), cache=cache)
    (entry,) = tmp_path.glob("*.json")
    entry.write_text(junk)
    timer = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(1), trials=4, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 1


def test_counts_only_entry_backfills_wall_time(tmp_path):
    """An entry cached by a counts-only gather reuses its counts and times
    once when a wall-time column is later requested."""
    gather_feature_table(["f_op_float32_mul"], _tiny_kernels(2),
                         timer=_fake_timer(),
                         cache=MeasurementCache(tmp_path, FP))
    timer = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=20, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 2
    timer2 = _fake_timer()
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=20, timer=timer2,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer2.calls == 0


# ---------------------------------------------------------------------------
# wall-time noise metadata (std/min alongside the median)
# ---------------------------------------------------------------------------


def _stats_timer():
    return CountingTimer(
        lambda k, trials: TimingStats(median=0.125, std=0.01, min=0.11))


def test_noise_metadata_lands_in_table_and_cache(tmp_path):
    cache = MeasurementCache(tmp_path, FP)
    table = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                 timer=_stats_timer(), cache=cache)
    assert set(table.row_noise) == set(table.row_names)
    for d in table.row_noise.values():
        assert d == {"median": 0.125, "std": 0.01, "min": 0.11}
    # warm run reproduces the noise metadata from the cache, zero timings
    warm = _stats_timer()
    table2 = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                  timer=warm,
                                  cache=MeasurementCache(tmp_path, FP))
    assert warm.calls == 0
    assert table2.row_noise == table.row_noise


def test_float_returning_timers_still_work_without_noise():
    table = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                 timer=_fake_timer())
    assert table.row_noise == {}
    assert list(table.values[:, 0]) == [0.125, 0.125]


def test_old_schema_entry_without_noise_still_reads_as_hit(tmp_path):
    """Entries written before noise metadata existed (no "noise" key) must
    stay hits — a schema addition must never invalidate a warm cache."""
    cache = MeasurementCache(tmp_path, FP)
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                         timer=_stats_timer(), cache=cache)
    for path in tmp_path.glob("*.json"):
        payload = json.loads(path.read_text())
        payload.pop("noise")
        path.write_text(json.dumps(payload))
    timer = _stats_timer()
    table = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                 timer=timer,
                                 cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 0                     # still fully warm
    assert table.row_noise == {}                # no metadata → none surfaced
    assert list(table.values[:, 0]) == [0.125, 0.125]


def test_malformed_noise_metadata_never_blocks_a_hit(tmp_path):
    cache = MeasurementCache(tmp_path, FP)
    gather_feature_table(FEATURES, _tiny_kernels(1), trials=4,
                         timer=_stats_timer(), cache=cache)
    (entry,) = tmp_path.glob("*.json")
    payload = json.loads(entry.read_text())
    payload["noise"] = {"median": "not-a-number"}
    entry.write_text(json.dumps(payload))
    timer = _stats_timer()
    gather_feature_table(FEATURES, _tiny_kernels(1), trials=4, timer=timer,
                         cache=MeasurementCache(tmp_path, FP))
    assert timer.calls == 0


def test_time_stats_reports_spread():
    (k,) = _tiny_kernels(1)
    stats = k.time_stats(trials=5, warmup=1)
    assert stats.median > 0
    assert stats.std is not None and stats.std >= 0
    assert stats.min is not None and 0 < stats.min <= stats.median
    assert k.time(trials=3) > 0                 # median shortcut unchanged


def test_timing_stats_coerce():
    s = TimingStats.coerce(0.5)
    assert s == TimingStats(median=0.5)
    assert TimingStats.coerce(s) is s
    assert s.to_dict() == {"median": 0.5}
    full = TimingStats(median=1.0, std=0.1, min=0.9)
    assert full.to_dict() == {"median": 1.0, "std": 0.1, "min": 0.9}


# ---------------------------------------------------------------------------
# CLI: cold run measures + writes profile; warm run is zero-timing and
# byte-identical (the acceptance property, in-process)
# ---------------------------------------------------------------------------


CLI_ARGS = ["--tags", "empty_kernel", "nelements:16,1024",
            "--match", "intersect",
            "--expr", "p_launch * f_sync_launch_kernel",
            "--trials", "2"]


def test_cli_cold_then_warm_zero_timings_identical_profile(tmp_path):
    cache_dir = str(tmp_path / "cache")
    p1, p2 = tmp_path / "prof1.json", tmp_path / "prof2.json"
    rc = calibrate_main(CLI_ARGS + ["--cache-dir", cache_dir,
                                    "--out", str(p1)])
    assert rc == 0
    rc = calibrate_main(CLI_ARGS + ["--cache-dir", cache_dir,
                                    "--out", str(p2),
                                    "--expect-zero-timings"])
    assert rc == 0
    assert p1.read_text() == p2.read_text()


def test_cli_expect_zero_timings_fails_on_cold_cache(tmp_path):
    rc = calibrate_main(CLI_ARGS + ["--cache-dir", str(tmp_path / "c"),
                                    "--out", str(tmp_path / "p.json"),
                                    "--expect-zero-timings"])
    assert rc == 1


def test_cli_no_matching_kernels_is_an_error(tmp_path):
    rc = calibrate_main(["--tags", "no_such_generator",
                         "--match", "identical",
                         "--out", str(tmp_path / "p.json")])
    assert rc == 2


# ---------------------------------------------------------------------------
# kernel-code signatures in cache keys (generator edits invalidate entries)
# ---------------------------------------------------------------------------


def _sig_kernels(n, code_sig):
    kernels = _tiny_kernels(n)
    for k in kernels:
        k.code_sig = code_sig
    return kernels


def test_code_signature_change_invalidates_cache_entries(tmp_path):
    """Editing a generator body (→ new source signature) must miss the old
    entries; the same signature stays a hit."""
    cache = MeasurementCache(tmp_path, FP)
    gather_feature_table(FEATURES, _sig_kernels(2, "sig_v1"), trials=4,
                         timer=_fake_timer(), cache=cache)
    same = _fake_timer()
    gather_feature_table(FEATURES, _sig_kernels(2, "sig_v1"), trials=4,
                         timer=same, cache=MeasurementCache(tmp_path, FP))
    assert same.calls == 0
    edited = _fake_timer()
    gather_feature_table(FEATURES, _sig_kernels(2, "sig_v2"), trials=4,
                         timer=edited, cache=MeasurementCache(tmp_path, FP))
    assert edited.calls == 2                    # every edited kernel re-timed


def test_old_format_entry_without_code_key_reads_as_miss(tmp_path):
    """Entries written before code signatures existed (key lacks "code")
    must read as misses, never be trusted."""
    from repro.checkpoint.manager import atomic_write_json

    cache = MeasurementCache(tmp_path, FP)
    (k,) = _tiny_kernels(1)
    old_key = {kk: v for kk, v in
               cache._key_payload(k.name, k.sizes, 4, k.code_sig).items()
               if kk != "code"}
    atomic_write_json(cache._path(old_key), {
        "key": old_key, "wall_time": 0.5,
        "counts": {"f_op_float32_mul": 8.0, "f_op_float32_add": 8.0}})
    timer = _fake_timer()
    table = gather_feature_table(FEATURES, [k], trials=4, timer=timer,
                                 cache=cache)
    assert timer.calls == 1                     # stale format ignored
    assert table.values[0, 0] == 0.125          # fresh measurement used


def test_generators_compute_and_propagate_code_signatures():
    from repro.core.uipick import MATMUL_SQ, source_signature

    assert MATMUL_SQ.code_sig                   # registration-time hash
    kernels = list(MATMUL_SQ.variants(
        {"n": (256,), "dtype": ("float32",), "prefetch": (False,),
         "tile": (16,)}))
    assert kernels and all(k.code_sig == MATMUL_SQ.code_sig
                           for k in kernels)

    def f1(x):
        return x + 1

    def f2(x):
        return x + 2

    assert source_signature(f1) != source_signature(f2)
    ns = {}
    exec("def no_source(x):\n    return x", ns)   # no retrievable source
    assert source_signature(ns["no_source"]) == ""
    assert source_signature(f1) == source_signature(f1)  # deterministic


# ---------------------------------------------------------------------------
# noisy-row re-measurement heuristic (retime_rel_std)
# ---------------------------------------------------------------------------


def _flaky_then_steady_timer():
    """First pass per kernel: 40% rel std; later passes: 0.8%."""
    seen = {}

    def timer(k, trials):
        n = seen.get(k.name, 0)
        seen[k.name] = n + 1
        std = 0.05 if n == 0 else 0.001
        return TimingStats(median=0.125, std=std, min=0.11)

    return CountingTimer(timer)


def test_retime_heuristic_retimes_noisy_rows_and_keeps_steadier():
    timer = _flaky_then_steady_timer()
    table = gather_feature_table(FEATURES, _tiny_kernels(3), trials=4,
                                 timer=timer, retime_rel_std=0.1)
    assert timer.calls == 6                     # one extra pass per row
    assert sorted(table.retimed_rows) == sorted(table.row_names)
    for d in table.row_noise.values():
        assert d["std"] == 0.001                # the steadier pass won


def test_retime_ignores_timers_without_spread_metadata():
    """A bare-seconds timer reports no std: rows are not retime-eligible
    (unknown spread must not read as infinitely noisy)."""
    timer = _fake_timer()
    table = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                 timer=timer, retime_rel_std=0.1)
    assert timer.calls == 2                     # exactly one pass per row
    assert table.retimed_rows == []


def test_retime_below_threshold_is_a_noop():
    timer = _flaky_then_steady_timer()
    table = gather_feature_table(FEATURES, _tiny_kernels(3), trials=4,
                                 timer=timer, retime_rel_std=0.5)
    assert timer.calls == 3
    assert table.retimed_rows == []


def test_retime_keeps_original_when_fresh_pass_is_noisier():
    def timer_fn(k, trials):
        return TimingStats(median=0.125, std=0.05, min=0.11)

    timer = CountingTimer(timer_fn)
    table = gather_feature_table(FEATURES, _tiny_kernels(1), trials=4,
                                 timer=timer, retime_rel_std=0.1)
    assert timer.calls == 2                     # retried once...
    assert table.retimed_rows == ["tiny_8"]
    assert table.values[0, 0] == 0.125          # ...but nothing degraded


def test_retime_applies_to_cached_rows_and_updates_cache(tmp_path):
    """A noisy CACHED row is the whole point: the warm run re-times it and
    the steadier measurement replaces the entry."""
    noisy = CountingTimer(
        lambda k, t: TimingStats(median=0.2, std=0.08, min=0.1))
    gather_feature_table(FEATURES, _tiny_kernels(2), trials=4, timer=noisy,
                         cache=MeasurementCache(tmp_path, FP))

    steady = CountingTimer(
        lambda k, t: TimingStats(median=0.125, std=0.001, min=0.124))
    table = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                 timer=steady,
                                 cache=MeasurementCache(tmp_path, FP),
                                 retime_rel_std=0.1)
    assert steady.calls == 2                    # re-timed despite warm cache
    assert list(table.values[:, 0]) == [0.125, 0.125]

    # the cache now carries the steadier measurement: a later plain gather
    # is fully warm AND below the threshold
    after = CountingTimer(
        lambda k, t: TimingStats(median=0.3, std=0.09, min=0.2))
    table2 = gather_feature_table(FEATURES, _tiny_kernels(2), trials=4,
                                  timer=after,
                                  cache=MeasurementCache(tmp_path, FP),
                                  retime_rel_std=0.1)
    assert after.calls == 0
    assert table2.retimed_rows == []
    assert list(table2.values[:, 0]) == [0.125, 0.125]
