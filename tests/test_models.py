"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions (deliverable f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import lm


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend.kind != "none":
        P = cfg.frontend.num_positions
        batch["frontend"] = jax.random.normal(
            key, (B, P, cfg.frontend.d_frontend), jnp.float32)
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch, rng):
    cfg = get_smoke_config(arch)
    params = lm.init(rng, cfg)
    batch = _batch(cfg, rng)
    logits, aux, _ = lm.forward(params, cfg, batch, mode="train")
    assert logits.shape == (2, 32, lm.padded_vocab(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = lm.lm_loss(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    assert loss > 0


@pytest.mark.slow  # compiles forward+backward for every arch (~1 min total)
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_grad_step_reduces_loss(arch, rng):
    cfg = get_smoke_config(arch)
    params = lm.init(rng, cfg)
    batch = _batch(cfg, rng)

    def loss_fn(p):
        return lm.lm_loss(p, cfg, batch)[0]

    l0, g = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                         for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(gnorm)) and gnorm > 0
    lr = 1e-2 / max(float(gnorm), 1.0)
    p2 = jax.tree.map(lambda p, gg: p - lr * gg.astype(p.dtype), params, g)
    l1 = loss_fn(p2)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_matches_no_remat(arch, rng):
    cfg = get_smoke_config(arch)
    params = lm.init(rng, cfg)
    batch = _batch(cfg, rng)
    l_full, _ = lm.lm_loss(params, cfg, batch, remat="full")
    l_none, _ = lm.lm_loss(params, cfg, batch, remat="none")
    assert abs(float(l_full) - float(l_none)) < 1e-4


def test_attn_impls_agree(rng):
    cfg = get_smoke_config("yi-6b")
    params = lm.init(rng, cfg)
    batch = _batch(cfg, rng)
    a, _, _ = lm.forward(params, cfg, batch, attn_impl="chunked_scan",
                         q_chunk=8, kv_chunk=8)
    b, _, _ = lm.forward(params, cfg, batch, attn_impl="chunked_tri",
                         q_chunk=8, kv_chunk=8)
    assert jnp.allclose(a, b, rtol=1e-4, atol=1e-4)
