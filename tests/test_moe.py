"""MoE dispatch mechanics: routing, capacity drops, combine weights."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.moe import _capacity, apply_moe


def _setup(cf=4.0, E=8, k=2):
    cfg = get_smoke_config("arctic-480b")
    cfg = cfg.replace(moe=cfg.moe.replace(capacity_factor=cf, num_experts=E,
                                          top_k=k))
    from repro.models.moe import moe_schema
    from repro.models.param import init_tree

    p = init_tree(jax.random.PRNGKey(0), moe_schema(cfg), jnp.float32)
    return cfg, p


def test_moe_output_finite_and_shaped():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = apply_moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux["moe_aux_loss"]) > 0


def test_no_drops_with_ample_capacity():
    cfg, p = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    _, aux = apply_moe(p, cfg, x)
    assert float(aux["moe_frac_dropped"]) == 0.0


def test_drops_with_tiny_capacity():
    cfg, p = _setup(cf=0.1)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    _, aux = apply_moe(p, cfg, x)
    assert float(aux["moe_frac_dropped"]) > 0.2


def test_capacity_formula_monotone():
    cfg, _ = _setup()
    m = cfg.moe
    caps = [_capacity(t, m) for t in (64, 256, 1024)]
    assert caps == sorted(caps)
    assert all(c % 8 == 0 for c in caps)


def test_moe_gradients_flow_to_experts():
    cfg, p = _setup()
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))

    def loss(p):
        y, aux = apply_moe(p, cfg, x)
        return jnp.sum(y ** 2) + aux["moe_aux_loss"]

    g = jax.grad(loss)(p)
    gw = float(jnp.sum(jnp.abs(g["w_up"])))
    gr = float(jnp.sum(jnp.abs(g["router"])))
    assert gw > 0 and gr > 0


def test_a2a_dispatch_matches_scatter():
    """shard_map all-to-all MoE must reproduce the scatter baseline
    (fwd + grad) at drop-free capacity — run on 8 fake devices."""
    import os
    import subprocess
    import sys

    code = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models.moe import apply_moe, moe_schema
from repro.models.moe_a2a import apply_moe_a2a
from repro.models.param import init_tree
from repro.sharding import use_mesh

cfg = get_smoke_config("deepseek-v2-236b")
cfg = cfg.replace(moe=cfg.moe.replace(capacity_factor=8.0))
p = init_tree(jax.random.PRNGKey(0), moe_schema(cfg), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
y_ref, _ = apply_moe(p, cfg, x)
mesh = make_mesh((2, 4), ("data", "model"))
with use_mesh(mesh):
    y_a2a, _ = jax.jit(lambda p, x: apply_moe_a2a(p, cfg, x))(p, x)
rel = float(jnp.max(jnp.abs(y_a2a - y_ref)) / jnp.max(jnp.abs(y_ref)))
assert rel < 1e-4, rel
print("A2A_OK", rel)
'''
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.getcwd())
    assert out.returncode == 0, out.stderr[-3000:]
    assert "A2A_OK" in out.stdout
