"""Serving-path correctness: prefill + decode vs whole-sequence forward.

The strongest invariant a KV/state cache can satisfy: decoding token t
after prefilling tokens [0, t) must reproduce the logits the full forward
pass assigns at position t-? — chunked-parallel train paths (SSD / mLSTM)
and recurrent decode paths are different algorithms for the same math.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import lm

# archs whose decode path is algebraically identical to forward (attention)
TOL = {
    "zamba2-7b": 2e-2,        # chunked SSD vs recurrent step
    "internvl2-2b": 2e-3,
    "granite-8b": 2e-3,
    "yi-6b": 2e-3,
    "nemotron-4-15b": 2e-3,
    "gemma2-9b": 2e-3,
    "whisper-tiny": 2e-3,
    "xlstm-125m": 5e-2,       # chunked mLSTM vs recurrent step
    "arctic-480b": 5e-2,      # MoE capacity drops can differ slightly
    "deepseek-v2-236b": 5e-2,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch, rng):
    cfg = get_smoke_config(arch)
    params = lm.init(rng, cfg)
    B, S = 2, 17
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch_full = {"tokens": tokens}
    if cfg.frontend.kind != "none":
        P = cfg.frontend.num_positions
        batch_full["frontend"] = jax.random.normal(
            rng, (B, P, cfg.frontend.d_frontend), jnp.float32)

    # full forward over all S tokens: logits at the last position
    logits_full, _, _ = lm.forward(params, cfg, batch_full, mode="train",
                                   q_chunk=8, kv_chunk=8)
    want = logits_full[:, -1]

    # prefill S-1 tokens, then decode token S-1
    cache = lm.zero_cache(cfg, B, 32)
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = tokens[:, : S - 1]
    cache, _ = lm.prefill(params, cfg, cache, batch_pre, q_chunk=8,
                          kv_chunk=8)
    n_front = cfg.frontend.num_positions \
        if cfg.frontend.kind != "none" and cfg.encdec is None else 0
    cur = jnp.asarray(S - 1 + n_front, jnp.int32)
    cache, logits_dec = lm.decode_step(
        params, cfg, cache, tokens[:, S - 1:], cur)
    got = logits_dec[:, 0]

    diff = jnp.max(jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(want.astype(jnp.float32))) + 1e-6
    rel = float(diff / scale)
    assert rel < TOL[arch], (arch, rel)


def test_local_ring_cache_matches_full(rng):
    """gemma2 ring-buffer window cache vs a cache big enough to be exact."""
    cfg = get_smoke_config("gemma2-9b")  # window=16 in smoke config
    params = lm.init(rng, cfg)
    B, S_pre, n_dec = 2, 24, 6  # prompt exceeds the window
    tokens = jax.random.randint(rng, (B, S_pre + n_dec), 0, cfg.vocab_size)

    cache = lm.zero_cache(cfg, B, 64)  # local layers get ring of 16
    batch = {"tokens": tokens[:, :S_pre]}
    cache, logits = lm.prefill(params, cfg, cache, batch, q_chunk=8,
                               kv_chunk=8)
    outs = []
    for t in range(n_dec):
        cache, lg = lm.decode_step(
            params, cfg, cache, tokens[:, S_pre + t: S_pre + t + 1],
            jnp.asarray(S_pre + t, jnp.int32))
        outs.append(lg)

    # reference: full forward over the whole sequence
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens}, mode="train",
                            q_chunk=8, kv_chunk=8)
    for t in range(n_dec):
        want = full[:, S_pre + t]  # logits at position S_pre+t
        got = outs[t][:, 0]
        diff = float(jnp.max(jnp.abs(got - want)))
        assert diff < 2e-2 * (float(jnp.max(jnp.abs(want))) + 1e-3), (t, diff)
