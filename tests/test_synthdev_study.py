"""Closed-loop cross-machine study on the synthetic ground-truth fleet.

The accuracy claims the paper makes from benchmark tables become
assertions here: calibration against devices with KNOWN parameters must
recover those parameters, and the zoo's scope ladder must show the
paper's accuracy ordering on held-out kernel variants.

Documented tolerances (see repro/testing/synthdev.py):
  * noiseless recovery: rtol ≤ 1e-5 (float32 LM; observed ~1e-7)
  * 2 % relative timing noise: rtol ≤ 5e-2 (observed ~1e-2)
"""
import numpy as np
import pytest

from repro.core.model import FeatureTable
from repro.core.uipick import CountingTimer, holdout_split
from repro.profiles import load_profile, save_profile
from repro.studies import (
    MODEL_ZOO,
    STUDY_SMOKE_TAGS,
    STUDY_TAGS,
    StudyError,
    compare_profiles,
    profile_accuracy,
    run_study,
)
from repro.testing.synthdev import SyntheticDevice, default_fleet, fleet_device

NOISELESS_RTOL = 1e-5
NOISY_RTOL = 5e-2
NOISE = 0.02


def _recovery_errors(profile, device, entry):
    mf = profile.fits[entry.name]
    return {p: abs(mf.params[p] - device.p_true[p]) / device.p_true[p]
            for p in entry.recoverable}


@pytest.mark.parametrize("entry", MODEL_ZOO, ids=lambda e: e.name)
def test_noiseless_recovery_all_devices(entry):
    """3 devices × every zoo model as truth: fitting the matching model
    form on noise-free synthetic timings recovers p_true almost exactly."""
    for device in default_fleet(truth=entry, noise=0.0):
        profile = run_study(fingerprint=device.fingerprint,
                            timer=device.timer, tags=STUDY_SMOKE_TAGS,
                            trials=3)
        errs = _recovery_errors(profile, device, entry)
        assert max(errs.values()) <= NOISELESS_RTOL, (device.name, errs)


def test_noisy_recovery_and_accuracy_ordering():
    """The paper's §8 shape end to end: 3 noisy devices, 3 zoo models
    fitted from one battery each; the matched (nonlinear-truth) model
    recovers ground truth within NOISY_RTOL and its held-out error is no
    worse than either linear model's on every machine."""
    profiles = []
    for device in default_fleet(noise=NOISE):
        profile = run_study(fingerprint=device.fingerprint,
                            timer=device.timer, tags=STUDY_TAGS, trials=3)
        errs = _recovery_errors(profile, device, device.truth)
        assert max(errs.values()) <= NOISY_RTOL, (device.name, errs)
        profiles.append(profile)

    report = compare_profiles(profiles)
    assert len(report.machines) == 3
    for fp in report.machines:
        s = report.summary[fp]
        assert s["ovl_flop_mem"] <= s["lin_flop"] * (1 + 1e-6), (fp, s)
        assert s["ovl_flop_mem"] <= s["lin_flop_mem"] * (1 + 1e-6), (fp, s)


def test_study_from_cached_synthetic_timings(tmp_path):
    """A second study over a warm cache performs ZERO timings and produces
    a byte-identical profile (synthetic determinism is order-independent)."""
    from repro.profiles import MeasurementCache

    device = fleet_device("citra", noise=NOISE)
    cold = CountingTimer(device.timer)
    p1 = run_study(fingerprint=device.fingerprint, timer=cold,
                   cache=MeasurementCache(tmp_path, device.fingerprint),
                   tags=STUDY_SMOKE_TAGS, trials=3)
    assert cold.calls == len(p1.kernel_names) > 0

    warm = CountingTimer(device.timer)
    p2 = run_study(fingerprint=device.fingerprint, timer=warm,
                   cache=MeasurementCache(tmp_path, device.fingerprint),
                   tags=STUDY_SMOKE_TAGS, trials=3)
    assert warm.calls == 0
    save_profile(p1, tmp_path / "a.json")
    save_profile(p2, tmp_path / "b.json")
    assert (tmp_path / "a.json").read_text() \
        == (tmp_path / "b.json").read_text()


def test_profile_roundtrip_preserves_study_artifacts(tmp_path):
    """Holdout table (values, row names, noise metadata) and every zoo fit
    survive the JSON round trip bit-exactly."""
    device = fleet_device("apex", noise=NOISE)
    profile = run_study(fingerprint=device.fingerprint, timer=device.timer,
                        tags=STUDY_SMOKE_TAGS, trials=3)
    path = save_profile(profile, tmp_path / "prof.json")
    loaded = load_profile(path, expected_fingerprint=device.fingerprint)
    assert sorted(loaded.fits) == sorted(e.name for e in MODEL_ZOO)
    for name in profile.fits:
        assert loaded.fits[name].params == profile.fits[name].params
    assert loaded.holdout is not None
    np.testing.assert_array_equal(loaded.holdout.values,
                                  profile.holdout.values)
    assert loaded.holdout.row_names == profile.holdout.row_names
    assert loaded.holdout.row_noise == profile.holdout.row_noise
    # and the loaded profile still yields the identical accuracy table
    assert profile_accuracy(loaded) == profile_accuracy(profile)


def test_synthetic_timer_is_deterministic_and_positive():
    device = fleet_device("bulk", noise=0.1)
    from repro.core.uipick import ALL_GENERATORS, KernelCollection, \
        MatchCondition
    kernels = KernelCollection(ALL_GENERATORS).generate_kernels(
        STUDY_SMOKE_TAGS, generator_match_cond=MatchCondition.INTERSECT)
    for k in kernels:
        s1 = device.timer(k, 3)
        s2 = device.timer(k, 3)
        assert s1 == s2
        assert s1.median > 0 and s1.min > 0
        assert s1.std == pytest.approx(0.1 * device.true_time(k))
        # a different trials count is a different measurement → new draw
        assert device.timer(k, 4).median != s1.median


def test_synthetic_fingerprint_distinguishes_truth_and_noise():
    base = fleet_device("apex")
    assert fleet_device("apex", noise=0.02).fingerprint != base.fingerprint
    from repro.studies import LIN_FLOP
    assert fleet_device("apex", truth=LIN_FLOP).fingerprint \
        != base.fingerprint
    assert base.fingerprint.platform == "synth"


def test_synthetic_device_validates_inputs():
    from repro.studies import OVL_FLOP_MEM
    with pytest.raises(KeyError, match="unknown synthetic device"):
        fleet_device("nope")
    with pytest.raises(ValueError, match="needs values"):
        SyntheticDevice(name="x", truth=OVL_FLOP_MEM,
                        p_true={"p_madd": 1e-11})
    with pytest.raises(ValueError, match="noise"):
        fleet_device("apex", noise=0.9)


def test_holdout_split_is_deterministic_and_disjoint():
    names = [f"kernel_{i}" for i in range(16)]
    table = FeatureTable(["f_x"], np.arange(16.0).reshape(16, 1), names)
    train1, hold1 = holdout_split(table, holdout_fraction=0.25)
    train2, hold2 = holdout_split(table, holdout_fraction=0.25)
    assert train1.row_names == train2.row_names
    assert hold1.row_names == hold2.row_names
    assert len(hold1) == 4                       # exact fraction
    assert set(train1.row_names) | set(hold1.row_names) == set(names)
    assert not set(train1.row_names) & set(hold1.row_names)
    # row order and values preserved through select
    for t in (train1, hold1):
        for i, n in enumerate(t.row_names):
            assert t.values[i, 0] == float(n.split("_")[1])
    # a different salt yields a different (but still deterministic) split
    _, hold_salt = holdout_split(table, holdout_fraction=0.25, salt="other")
    assert hold_salt.row_names != hold1.row_names


def test_holdout_split_bounds():
    table = FeatureTable(["f_x"], np.zeros((2, 1)), ["a", "b"])
    train, hold = holdout_split(table, holdout_fraction=0.0)
    assert len(hold) == 1 and len(train) == 1     # both sides non-empty
    train, hold = holdout_split(table, holdout_fraction=1.0)
    assert len(hold) == 1 and len(train) == 1
    with pytest.raises(ValueError, match="cannot split"):
        holdout_split(FeatureTable(["f_x"], np.zeros((1, 1)), ["a"]))


def test_run_study_validates_holdout_fraction():
    device = fleet_device("apex")
    for bad in (0.0, 1.0, -0.1, 1.5):
        with pytest.raises(StudyError, match="holdout_fraction"):
            run_study(fingerprint=device.fingerprint, timer=device.timer,
                      tags=STUDY_SMOKE_TAGS, trials=3,
                      holdout_fraction=bad)


def test_relative_errors_rejects_missing_feature_columns():
    """A fit whose features were never gathered must error, not be scored
    against silently-zero columns (fabricated accuracy)."""
    from repro.core.calibrate import relative_errors
    from repro.core.model import Model

    table = FeatureTable(["f_wall_time_x", "f_a"],
                         np.asarray([[1.0, 2.0], [2.0, 3.0]]), ["k0", "k1"])
    model = Model("f_wall_time_x", "p_u * f_a + p_v * f_missing")
    with pytest.raises(ValueError, match="f_missing"):
        relative_errors(model, {"p_u": 1.0, "p_v": 1.0}, table)
    # a missing OUTPUT column is a missing-column error too, not a
    # misleading "output is zero" complaint
    other = Model("f_wall_time_other", "p_u * f_a")
    with pytest.raises(ValueError, match="lacks columns.*f_wall_time_other"):
        relative_errors(other, {"p_u": 1.0}, table)


def test_run_study_rejects_underdetermined_battery():
    """A battery whose train split has fewer rows than the widest model
    has parameters must error instead of persisting arbitrary fits."""
    device = fleet_device("apex")
    with pytest.raises(StudyError, match="underdetermined|widest zoo"):
        run_study(fingerprint=device.fingerprint, timer=device.timer,
                  tags=["empty_kernel", "nelements:16,1024"], trials=3)


def test_compare_rejects_duplicate_machine_and_missing_holdout():
    device = fleet_device("apex", noise=NOISE)
    profile = run_study(fingerprint=device.fingerprint, timer=device.timer,
                        tags=STUDY_SMOKE_TAGS, trials=3)
    with pytest.raises(StudyError, match="more than once"):
        compare_profiles([profile, profile])
    with pytest.raises(StudyError, match="at least 2"):
        compare_profiles([profile])
    from repro.profiles import MachineProfile
    bare = MachineProfile(fingerprint=fleet_device("bulk").fingerprint,
                          fits=dict(profile.fits))
    with pytest.raises(StudyError, match="no held-out"):
        compare_profiles([profile, bare])
