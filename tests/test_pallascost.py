"""Static Pallas cost analyzer: grid-scaled counts and block-spec HBM
traffic match closed-form ground truth — with zero kernel executions.

``pallas_call`` is no longer opaque: :mod:`repro.analysis.pallascost`
walks the kernel-body jaxpr abstractly, scales per-program counts by the
grid size, and derives HBM↔VMEM traffic from each operand's BlockSpec
(block shape × index-map refetch pattern × grid extent).  These tests pin
the derived features against hand-computed formulas for the three
canonical wrappers — matmul, stencil5, flash_attention — at ≥ 3 shapes
each, entirely from ``ShapeDtypeStruct`` arguments (no device arrays
exist to execute), with kernel timing POISONED for good measure.

A deliberately non-affine fixture (index map ``i * i``) pins the failure
mode: the counter stays silent (no fabricated features) and the scope
auditor reports the precise ``pallas-unanalyzable`` diagnostic instead of
a blanket opacity error.
"""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import PallasUnanalyzable, audit_callable
from repro.analysis.pallascost import (
    BYTES_IN_FEATURE,
    BYTES_OUT_FEATURE,
    unanalyzable_reason,
)
from repro.api import PerfSession
from repro.core.calibrate import FitResult
from repro.core.counting import count_fn
from repro.core.model import Model
from repro.core.uipick import CountingTimer, MeasurementKernel
from repro.kernels import ops
from repro.profiles import DeviceFingerprint, MachineProfile, ModelFit


def _profile() -> MachineProfile:
    """A tiny in-memory profile whose overlap model prices the madd and
    contiguous-memory features the analyzer derives (no file, no device)."""
    model = Model(
        "f_wall_time_cpu_host",
        "overlap2(p_madd * f_op_float32_madd, "
        "p_mem * (f_mem_contig_float32_load "
        "+ f_mem_contig_float32_store + f_op_float32_add), p_edge) "
        "+ p_launch * f_sync_launch_kernel")
    fit = FitResult(params={"p_madd": 5e-11, "p_mem": 4e-10,
                            "p_launch": 3e-6, "p_edge": 40.0},
                    residual_norm=0.0, iterations=1, converged=True)
    return MachineProfile(
        fingerprint=DeviceFingerprint(platform="synth",
                                      device_kind="pallas-test",
                                      n_devices=1),
        fits={"ovl_flop_mem": ModelFit.from_fit(model, fit)},
        trials=4)


@pytest.fixture(autouse=True)
def no_execution(monkeypatch):
    def boom(self, *a, **k):
        raise AssertionError(
            "static pallas analysis must never execute a kernel")

    monkeypatch.setattr(MeasurementKernel, "time", boom)
    monkeypatch.setattr(MeasurementKernel, "time_stats", boom)
    monkeypatch.setattr(MeasurementKernel, "jitted", boom)


def _f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ---------------------------------------------------------------------------
# ground truth: grid-scaled body counts and block-spec byte traffic
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,N,K,b", [
    (256, 384, 512, 128),
    (128, 128, 128, 128),
    (512, 256, 128, 64),
])
def test_matmul_counts_match_closed_form(M, N, K, b):
    fn = functools.partial(ops.matmul, block_m=b, block_n=b, block_k=b)
    c = count_fn(fn, _f32(M, K), _f32(K, N))
    gm, gn, gk = M // b, N // b, K // b
    # every (m, n, k) grid program multiplies one b×b×b tile pair
    assert c["f_op_float32_madd"] == M * N * K
    # A and B each refetch a b×b block at every grid step (k varies
    # fastest → the A block changes whenever k does, B always)
    assert c[BYTES_IN_FEATURE] == 4 * gm * gn * gk * (b * b + b * b)
    # the output block is written once per (m, n) tile
    assert c[BYTES_OUT_FEATURE] == 4 * M * N
    # block traffic is also priced in elements for the stock memory term
    assert c["f_mem_contig_float32_load"] == 2 * gm * gn * gk * b * b
    assert c["f_sync_grid_programs"] == gm * gn * gk


@pytest.mark.parametrize("M,N,bm,bn", [
    (256, 512, 128, 128),
    (256, 256, 128, 128),
    (512, 512, 256, 128),
])
def test_stencil5_counts_match_closed_form(M, N, bm, bn):
    fn = functools.partial(ops.stencil5, block_m=bm, block_n=bn)
    c = count_fn(fn, _f32(M, N))
    gm, gn = M // bm, N // bn
    # haloed input block: (bm+2)×(bn+2) floats per grid program
    assert c[BYTES_IN_FEATURE] == 4 * gm * gn * (bm + 2) * (bn + 2)
    assert c[BYTES_OUT_FEATURE] == 4 * M * N
    # 5-point stencil: 4 adds + 1 scale per output element
    assert c["f_op_float32_add"] == 4 * M * N
    assert c["f_op_float32_mul"] == M * N


@pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk", [
    (2, 256, 8, 2, 64, 64, 64),
    (1, 128, 4, 4, 64, 64, 64),
    (2, 512, 8, 2, 64, 128, 64),
])
def test_flash_attention_counts_match_closed_form(B, S, Hq, Hkv, D, bq, bk):
    fn = functools.partial(ops.flash_attention, causal=True,
                           block_q=bq, block_k=bk)
    c = count_fn(fn, _f32(B, S, Hq, D), _f32(B, S, Hkv, D),
                 _f32(B, S, Hkv, D))
    nq, nk = S // bq, S // bk
    # QK^T (S·S·D) plus PV (S·S·D) per (batch, q-head)
    assert c["f_op_float32_madd"] == B * Hq * S * S * (D + D)
    # Q fetched once per q-block; K and V refetched for every (q, k) pair
    # — the GQA head map (floor-div index map) changes nothing per-block
    q_bytes = 4 * B * Hq * nq * bq * D
    k_bytes = 4 * B * Hq * nq * nk * bk * D
    v_bytes = 4 * B * Hq * nq * nk * bk * D
    assert c[BYTES_IN_FEATURE] == q_bytes + k_bytes + v_bytes
    assert c[BYTES_OUT_FEATURE] == 4 * B * Hq * S * D
    # exp over every bq×bk score tile + one per-row rescale exp
    assert c["f_op_float32_transc"] == B * Hq * nq * nk * (bq * bk + bq)


# ---------------------------------------------------------------------------
# the unanalyzable path: precise diagnostic, silent counter
# ---------------------------------------------------------------------------


def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _nonaffine(x):
    # index map multiplies two grid-dependent values: no affine footprint
    return pl.pallas_call(
        _copy_body,
        grid=(4,),
        in_specs=[pl.BlockSpec((16, 64), lambda i: (i * i, 0))],
        out_specs=pl.BlockSpec((16, 64), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((64, 64), jnp.float32),
        interpret=True)(x)


def test_nonaffine_index_map_is_flagged_not_counted():
    args = (_f32(256, 64),)
    jaxpr = jax.make_jaxpr(_nonaffine)(*args)
    (eqn,) = [e for e in jaxpr.jaxpr.eqns
              if e.primitive.name == "pallas_call"]
    reason = unanalyzable_reason(eqn)
    assert isinstance(reason, PallasUnanalyzable)
    assert reason.reason == "non-affine-index-map"

    # the counter contributes NOTHING rather than fabricating traffic
    c = count_fn(_nonaffine, *args)
    assert BYTES_IN_FEATURE not in c and BYTES_OUT_FEATURE not in c
    assert not any(f.startswith("f_mem_contig") for f in c)

    # ... and the scope auditor reports the precise diagnostic
    diags = audit_callable(_nonaffine, args, "kernel:nonaffine")
    flagged = [d for d in diags if d.code == "pallas-unanalyzable"]
    assert len(flagged) == 1 and flagged[0].severity == "error"
    assert flagged[0].details["reason"] == "non-affine-index-map"
    assert not any(d.code == "opaque-primitive" for d in diags)


def test_analyzable_wrappers_audit_clean_of_pallas_codes():
    diags = audit_callable(
        functools.partial(ops.matmul, block_m=128, block_n=128,
                          block_k=128),
        (_f32(256, 256), _f32(256, 256)), "kernel:matmul")
    assert not any(d.code in ("opaque-primitive", "pallas-unanalyzable")
                   for d in diags)


# ---------------------------------------------------------------------------
# end-to-end: PerfSession prices a pallas wrapper with zero timings
# ---------------------------------------------------------------------------


def test_session_predicts_pallas_wrapper_with_memory_term():
    session = PerfSession.open(
        _profile(), timer=CountingTimer(lambda k, t: 0.125))
    fn = functools.partial(ops.matmul, block_m=128, block_n=128,
                           block_k=128)
    (pred,) = session.predict_batch([(fn, (_f32(256, 256), _f32(256, 256)))],
                                    names=["matmul"])
    assert session.timer.calls == 0
    assert pred.seconds > 0
    # the overlap model's memory operand is fed by the statically derived
    # block traffic — the memory term must carry real weight
    mem_terms = {k: v for k, v in pred.breakdown.items()
                 if "f_mem_contig_float32_load" in k}
    assert mem_terms and sum(mem_terms.values()) > 0


# ---------------------------------------------------------------------------
# grid-edge branches: pl.when charged to the programs that execute it
# ---------------------------------------------------------------------------


def _find_pallas_eqn(jaxpr):
    """The pallas_call equation anywhere under ``jaxpr`` (the wrappers
    jit, so it sits inside a pjit sub-jaxpr)."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            return eqn
        for val in eqn.params.values():
            inner = getattr(val, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                found = _find_pallas_eqn(inner)
                if found is not None:
                    return found
    return None


@pytest.mark.parametrize("M,N,K,b", [
    (512, 512, 1024, 256),
    (256, 256, 512, 128),
])
def test_matmul_grid_edge_when_blocks_counted_exactly(M, N, K, b):
    """The accumulator init (``pl.when(k == 0)``) runs on gm·gn programs
    and the flush (``pl.when(k == n_k - 1)``) on another gm·gn — not on
    all P = gm·gn·nk.  Per-program predicate resolution makes the VMEM
    ref counts land on the exact closed form instead of the branch
    average."""
    fn = functools.partial(ops.matmul, block_m=b, block_n=b, block_k=b)
    c = count_fn(fn, _f32(M, K), _f32(K, N))
    gm, gn, nk = M // b, N // b, K // b
    P = gm * gn * nk
    # stores: every program stores the += accumulator; k==0 programs also
    # store the zero init; k==nk-1 programs store the o_ref write
    assert c["f_vmem_ref_float32_store"] == b * b * (P + 2 * gm * gn)
    # loads: a/b tiles + the += accumulator read on every program, plus
    # the flush's accumulator read on the last-k programs only
    assert c["f_vmem_ref_float32_load"] == b * b * (3 * P + gm * gn)
    # the += add itself runs on every program, edge blocks add nothing
    assert c["f_op_float32_add"] == b * b * P


def test_matmul_branch_resolution_emits_no_averaging_note():
    from repro.analysis.pallascost import analyze_pallas_call

    fn = functools.partial(ops.matmul, block_m=128, block_n=128,
                           block_k=128)
    jaxpr = jax.make_jaxpr(fn)(_f32(256, 256), _f32(256, 256))
    eqn = _find_pallas_eqn(jaxpr.jaxpr)
    assert eqn is not None
    cost = analyze_pallas_call(eqn)
    # both pl.when predicates are affine in program_id(2): resolved, not
    # averaged — the analyzer has nothing to warn about
    assert cost.notes == ()


def _data_dependent_when(x):
    def body(x_ref, o_ref):
        @pl.when(x_ref[0, 0] > 0.0)
        def _():
            o_ref[...] = x_ref[...] + 1.0

    return pl.pallas_call(
        body,
        grid=(4,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((32, 128), jnp.float32),
        interpret=True)(x)


def test_data_dependent_when_falls_back_to_average_with_note():
    from repro.analysis.pallascost import analyze_pallas_call

    args = (_f32(32, 128),)
    jaxpr = jax.make_jaxpr(_data_dependent_when)(*args)
    eqn = _find_pallas_eqn(jaxpr.jaxpr)
    cost = analyze_pallas_call(eqn)
    assert len(cost.notes) == 1
    assert "not a resolvable function of program_id" in cost.notes[0]
    # averaged: 4 programs × 1024 adds × 1/2 branch weight
    c = count_fn(_data_dependent_when, *args)
    assert c["f_op_float32_add"] == 4 * 8 * 128 // 2


def test_averaged_branch_surfaces_as_info_diagnostic():
    diags = audit_callable(_data_dependent_when, (_f32(32, 128),),
                           "kernel:ddwhen")
    flagged = [d for d in diags if d.code == "pallas-averaged-branch"]
    assert len(flagged) == 1 and flagged[0].severity == "info"
    assert "averaged" in flagged[0].message
    # resolvable grid-edge branches (matmul) must NOT trigger the note
    clean = audit_callable(
        functools.partial(ops.matmul, block_m=128, block_n=128,
                          block_k=128),
        (_f32(256, 256), _f32(256, 256)), "kernel:matmul")
    assert not any(d.code == "pallas-averaged-branch" for d in clean)
